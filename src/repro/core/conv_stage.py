"""Snapshot-batched conventional-compression stage shared by every engine.

The three engines used to carry their own per-field loop around
``compressors.compress`` (serial: upfront over the snapshot; batched: lazily
per training group; streaming: per field on the reader side).  This module is
the one conventional stage they all call now: it plans the fields it is
handed into groups of identical ``(shape, dtype, error-bound spec)`` and
runs each group through the compressor's *batched* entry point when its
registry entry declares the capability
(:class:`repro.compressors.registry.CompressorEntry.compress_batched`).
With the run's historical single scalar bound every field shares one spec
and the plan degenerates to the original ``(shape, dtype)`` grouping; with
per-field :class:`repro.core.bounds.ErrorBound` specs, fields that share a
spec still batch and fields with distinct bounds split into their own
groups (a fused dispatch hands ``compress_batched`` exactly one spec).

The batched entries execute the group as ONE stacked op sequence (a single
device-op stream for the whole group instead of one per field) and are
contractually **byte-identical** to the per-field path, so archives stay
bit-compatible across engines no matter which path compressed a given field.
Compressors whose entry does not declare batchability — or whose capability
metadata excludes the group's dtype — fall back per-field.

:class:`ConvStats` counts how the work was actually dispatched (groups,
fused calls, per-field fallbacks); engines surface it under
``timing["conv_stage"]`` and the bench smoke profile fails if a multi-field
snapshot regresses to per-field call counts.
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Mapping

import numpy as np

from ..compressors import registry
from ..obs import telemetry as obs


def _accepts_lowering(fn) -> bool:
    """True iff ``fn`` takes a ``lowering`` kwarg (registry entries may wrap
    third-party compressors that know nothing about kernel dispatch)."""
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
    return ("lowering" in params
            or any(p.kind is inspect.Parameter.VAR_KEYWORD
                   for p in params.values()))


@dataclasses.dataclass
class ConvStats:
    """How the conventional stage dispatched its work.

    ``calls`` is the structural dispatch count: one per fused group call
    plus one per per-field fallback — the number the smoke-profile
    regression guard compares against ``fields``.
    """

    fields: int = 0
    groups: int = 0
    batched_fields: int = 0
    fallback_fields: int = 0
    calls: int = 0
    conv_s: float = 0.0
    # Dispatch calls that carried the kernel-lowering request through to the
    # compressor entry (0 for third-party entries without a lowering kwarg).
    lowered_calls: int = 0
    lowering: str = "auto"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def plan_groups(metas: Mapping[str, tuple],
                keys: Mapping[str, tuple] | None = None) -> list[list[str]]:
    """Group field names by ``(shape, dtype[, key])``, preserving input order.

    ``metas`` maps name -> ``(shape, dtype)``.  Fields of one group can run
    through a batched compressor entry as a stacked array.  ``keys``
    optionally refines the plan with a per-field hashable (the error-bound
    spec): fields only share a group when their keys agree too.
    """
    groups: dict[tuple, list[str]] = {}
    for name, (shape, dtype) in metas.items():
        k = (tuple(shape), str(np.dtype(dtype)),
             keys[name] if keys is not None else None)
        groups.setdefault(k, []).append(name)
    return list(groups.values())


class ConvStage:
    """Plan/executor for the conventional stage of one compression run.

    Holds the compressor registry entry and the run's error-bound spec;
    every engine funnels its fields through :meth:`run` (all at once, per
    training group, or per transient aux load) and reads the accumulated
    :class:`ConvStats` afterwards.
    """

    def __init__(self, compressor: str, rel_eb: float | None = None,
                 abs_eb: float | None = None, *, batch: bool = True,
                 bounds: Mapping | None = None, telemetry=None,
                 lowering: str = "auto"):
        self.entry = registry.get(compressor)   # unknown name -> ValueError
        self.rel_eb = rel_eb
        self.abs_eb = abs_eb
        self.batch = batch
        # Per-field ErrorBound specs; fields absent here use the run scalars.
        self.bounds = dict(bounds) if bounds else None
        self.lowering = lowering
        # The lowering request rides along only when the entry declares a
        # ``lowering`` kwarg — third-party compressor entries are untouched.
        self._lower_kw = ({"lowering": lowering}
                          if _accepts_lowering(self.entry.compress) else {})
        self._lower_kw_batched = (
            {"lowering": lowering}
            if (self.entry.compress_batched is not None
                and _accepts_lowering(self.entry.compress_batched)) else {})
        self.stats = ConvStats(lowering=lowering)
        self.tel = telemetry if telemetry is not None else obs.NULL

    def bound_for(self, name: str) -> tuple[float | None, float | None]:
        """``(rel_eb, abs_eb)`` this run will hand the compressor for one
        field (abs takes precedence inside the compressor entry points).
        Doubles as the plan's grouping key — the spec's ``conv_key``."""
        if self.bounds is not None and name in self.bounds:
            return self.bounds[name].conv_key()
        return (self.rel_eb, self.abs_eb)

    def plan(self, metas: Mapping[str, tuple]) -> list[list[str]]:
        keys = ({n: self.bound_for(n) for n in metas}
                if self.bounds is not None else None)
        return plan_groups(metas, keys=keys)

    def run(self, fields: Mapping[str, np.ndarray], *,
            batch: bool | None = None
            ) -> dict[str, tuple[dict, np.ndarray]]:
        """Compress ``fields``; returns ``{name: (archive, reconstruction)}``.

        Same-``(shape, dtype)`` groups go through the fused batched entry
        when the registry capability allows it; everything else runs
        per-field.  Output payloads are byte-identical either way.
        ``batch`` overrides the stage default for this call (the streaming
        scheduler turns it off when the fused path's working set would not
        fit its residency budget).
        """
        batch = self.batch if batch is None else batch
        t0 = time.time()
        out: dict[str, tuple[dict, np.ndarray]] = {}
        arrs = {n: np.asarray(x) for n, x in fields.items()}
        metas = {n: (a.shape, a.dtype) for n, a in arrs.items()}
        tel = self.tel
        with tel.span("conv", fields=len(arrs)) as sp:
            calls0 = self.stats.calls
            for group in self.plan(metas):
                self.stats.groups += 1
                tel.counter("conv.groups").add()
                tel.gauge("conv.group_size").set(len(group))
                dtype = metas[group[0]][1]
                rel, ab = self.bound_for(group[0])  # one spec/group, by plan
                if (batch and len(group) > 1
                        and self.entry.batch_supports(dtype)):
                    results = self.entry.compress_batched(
                        [arrs[n] for n in group], rel, abs_eb=ab,
                        **self._lower_kw_batched)
                    self.stats.calls += 1
                    self.stats.batched_fields += len(group)
                    self.stats.lowered_calls += bool(self._lower_kw_batched)
                    tel.counter("conv.dispatches").add()
                    tel.counter("conv.batched_fields").add(len(group))
                    out.update(zip(group, results))
                else:
                    for n in group:
                        out[n] = self.entry.compress(arrs[n], rel, abs_eb=ab,
                                                     **self._lower_kw)
                        self.stats.calls += 1
                        self.stats.fallback_fields += 1
                        self.stats.lowered_calls += bool(self._lower_kw)
                        tel.counter("conv.dispatches").add()
                        tel.counter("conv.fallback_fields").add()
            sp.set(calls=self.stats.calls - calls0)
        self.stats.fields += len(arrs)
        self.stats.conv_s += time.time() - t0
        return out
