import numpy as np

from repro.data import fields as F
from repro.data.tokens import TokenStream


def test_field_shapes_dtypes():
    for ds in ("nyx", "miranda", "hurricane"):
        flds = F.make_fields(ds, shape=(16, 16, 16), seed=0)
        assert set(flds) == set(F.DATASET_FIELDS[ds])
        for v in flds.values():
            assert v.shape == (16, 16, 16)
            assert str(v.dtype) == F.DATASET_DTYPES[ds]
            assert np.isfinite(v).all()


def test_cross_field_correlation_present():
    """The shared-latent construction must induce |corr| > 0.3 — that's the
    physics cross-field learning exploits."""
    flds = F.make_fields("nyx", shape=(24, 24, 24), seed=1, coupling=0.8)
    t = np.log(np.maximum(flds["temperature"].ravel(), 1e-9))
    d = np.log(np.maximum(flds["dark_matter_density"].ravel(), 1e-9))
    corr = np.corrcoef(t, d)[0, 1]
    assert abs(corr) > 0.3, corr


def test_coupling_zero_decorrelates():
    flds = F.make_fields("nyx", shape=(24, 24, 24), seed=1, coupling=0.0)
    t = np.log(np.maximum(flds["temperature"].ravel(), 1e-9))
    d = np.log(np.maximum(flds["dark_matter_density"].ravel(), 1e-9))
    assert abs(np.corrcoef(t, d)[0, 1]) < 0.3


def test_token_stream_deterministic_replay():
    s1 = TokenStream(1000, 4, 64, seed=7)
    a = [s1.next_batch() for _ in range(3)]
    state = s1.checkpoint()
    b = [s1.next_batch() for _ in range(2)]
    s2 = TokenStream(1000, 4, 64, seed=7)
    s2.restore(state)
    c = [s2.next_batch() for _ in range(2)]
    for x, y in zip(b, c):
        assert np.array_equal(x, y)
    s3 = TokenStream(1000, 4, 64, seed=7)
    for x in a:
        assert np.array_equal(x, s3.next_batch())


def test_token_stream_vocab_range():
    s = TokenStream(512, 2, 128, seed=0)
    t = s.next_batch()
    assert t.min() >= 0 and t.max() < 512
