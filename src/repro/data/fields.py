"""Synthetic scientific-field generators, statistically matched to the
paper's datasets (SDRBench originals are not redistributable offline).

Each generator builds correlated multi-field blocks from a *shared latent*
Gaussian random field plus field-specific components — mirroring how Nyx's
Temperature / Dark-Matter-Density / Baryon-Density are coupled through the
same governing equations (§3.4), which is exactly what cross-field learning
exploits.  Spectral slopes and value-range transforms per dataset family:

  nyx       — cosmology: log-normal density fields (huge dynamic range, like
              Baryon Density's 4.8e6 range), power-law spectrum k^-3
  miranda   — large turbulence, FP64, smooth k^-5/3 Kolmogorov-like spectra
  hurricane — weather: anisotropic (stratified) spectra, FP32
"""
from __future__ import annotations

import numpy as np


def _grf(rng: np.random.Generator, shape, slope: float,
         aniso: tuple = None) -> np.ndarray:
    """Gaussian random field with isotropic power spectrum ~ k^-slope."""
    kfreqs = [np.fft.fftfreq(n) * n for n in shape]
    grids = np.meshgrid(*kfreqs, indexing="ij")
    if aniso:
        grids = [g * a for g, a in zip(grids, aniso)]
    k2 = sum(g ** 2 for g in grids)
    k2[(0,) * len(shape)] = 1.0
    amp = k2 ** (-slope / 4.0)  # power ~ k^-slope  => amplitude ~ k^-slope/2
    amp[(0,) * len(shape)] = 0.0
    noise = rng.standard_normal(shape) + 1j * rng.standard_normal(shape)
    f = np.fft.ifftn(np.fft.fftn(noise) * amp).real
    f -= f.mean()
    sd = f.std()
    return f / (sd if sd > 0 else 1.0)


def make_fields(dataset: str = "nyx", shape=(64, 64, 64), seed: int = 0,
                coupling: float = 0.8) -> dict[str, np.ndarray]:
    """Correlated multi-field block for one synthetic dataset.

    ``coupling`` sets the shared-latent fraction (cross-field correlation).
    """
    rng = np.random.default_rng(seed)
    c = float(np.clip(coupling, 0.0, 1.0))
    w_shared, w_own = np.sqrt(c), np.sqrt(1.0 - c)

    if dataset == "nyx":
        latent = _grf(rng, shape, slope=3.0)
        def mix(slope):
            return w_shared * latent + w_own * _grf(rng, shape, slope)
        temp = (np.exp(1.2 * mix(3.0)) * 1e4).astype(np.float32)        # K-like
        dmd = (np.exp(2.0 * mix(2.8))).astype(np.float32)               # overdensity
        baryon = (np.exp(2.2 * (c * np.log(np.maximum(dmd, 1e-6)) / 2.0
                                + (1 - c) * mix(2.6)))).astype(np.float32)
        vy = (mix(3.2) * 2.5e7).astype(np.float32)                      # cm/s-like
        return {"temperature": temp, "dark_matter_density": dmd,
                "baryon_density": baryon, "velocity_y": vy}

    if dataset == "miranda":
        latent = _grf(rng, shape, slope=5.0 / 3.0 + 2.0)  # smooth turbulence
        def mix(slope):
            return w_shared * latent + w_own * _grf(rng, shape, slope)
        diff = (1.0 + 0.3 * mix(3.6)).astype(np.float64)
        visc = (1.0 + 0.25 * (c * (diff - 1.0) / 0.3 + (1 - c) * mix(3.5))).astype(np.float64)
        velz = (mix(3.7) * 0.8).astype(np.float64)
        return {"diffusivity": diff, "viscosity": visc, "velocity_z": velz}

    if dataset == "hurricane":
        aniso = (4.0, 1.0, 1.0)  # stratified atmosphere: steep vertical spectrum
        latent = _grf(rng, shape, slope=2.6, aniso=aniso)
        def mix(slope):
            return w_shared * latent + w_own * _grf(rng, shape, slope, aniso=aniso)
        cloud = np.maximum(mix(2.6) - 0.8, 0.0).astype(np.float32) * 1e-3  # sparse/spiky
        precip = np.maximum(mix(2.4) - 1.0, 0.0).astype(np.float32) * 5e-3
        w = (mix(2.9) * 8.0).astype(np.float32)
        return {"cloud": cloud, "precip": precip, "w": w}

    raise ValueError(f"unknown dataset {dataset!r}")


def snapshot_specs(num_fields: int, shape=(16, 32, 32), dataset: str = "nyx",
                   seed0: int = 2) -> dict[str, dict]:
    """Lazy per-field recipes for a ``num_fields``-field snapshot.

    Names match :func:`benchmarks.common.snapshot_fields` exactly
    (``{field}_s{seed}`` over successive seed blocks), but nothing is
    generated here — :func:`load_spec` materializes one field at a time, so
    the streaming pipeline can ingest snapshots far larger than memory
    (``repro.streaming.source.synthetic_snapshot_source`` wraps this)."""
    specs: dict[str, dict] = {}
    seed = seed0
    while len(specs) < num_fields:
        for name in DATASET_FIELDS[dataset]:
            if len(specs) < num_fields:
                specs[f"{name}_s{seed}"] = {"dataset": dataset,
                                            "shape": tuple(shape),
                                            "seed": seed, "field": name}
        seed += 1
    return specs


def load_spec(spec: dict) -> np.ndarray:
    """Materialize one snapshot field from its recipe.

    Regenerates only that field's seed block (the shared-latent coupling
    means a block's fields come from one RNG pass), so transient memory is
    one block regardless of snapshot size.  Deterministic: repeated loads
    return identical bytes, as the streaming source contract requires."""
    block = make_fields(spec["dataset"], shape=spec["shape"],
                        seed=spec["seed"])
    return block[spec["field"]]


DATASET_DTYPES = {"nyx": "float32", "miranda": "float64", "hurricane": "float32"}
DATASET_FIELDS = {
    "nyx": ["temperature", "dark_matter_density", "baryon_density", "velocity_y"],
    "miranda": ["diffusivity", "viscosity", "velocity_z"],
    "hurricane": ["cloud", "precip", "w"],
}
# Cross-field partner map used by benchmarks (paper §3.4/§5.2: T predicted
# with DMD help; baryon with DMD; etc.).
DEFAULT_CROSS_FIELD = {
    "nyx": {"temperature": ("dark_matter_density",),
            "baryon_density": ("dark_matter_density",),
            "dark_matter_density": ("temperature",),
            "velocity_y": ("temperature",)},
    "miranda": {"diffusivity": ("viscosity",), "viscosity": ("diffusivity",),
                "velocity_z": ("diffusivity",)},
    "hurricane": {"cloud": ("w",), "precip": ("cloud",), "w": ("precip",)},
}
