"""Fault-tolerance utilities: straggler watchdog, failure injection, restart.

At 1000+ nodes the common failures are (a) a host dying (handled by
checkpoint/restart — the trainer resumes from ``latest_step`` with identical
data order via the checkpointable token stream) and (b) stragglers (handled
by a per-step deadline watchdog that records overruns and can trigger a
preemptive checkpoint so the scheduler can replace the slow host).
"""
from __future__ import annotations

import threading
import time


class StepWatchdog:
    """Per-step deadline monitor.

    ``with watchdog.step(i): run_step()`` — if the step exceeds
    ``deadline_s``, the overrun is recorded and ``on_straggler`` fires (e.g.
    request an early checkpoint).  Pure-host logic; no device sync.
    """

    def __init__(self, deadline_s: float, on_straggler=None):
        self.deadline_s = deadline_s
        self.on_straggler = on_straggler
        self.overruns: list[tuple[int, float]] = []
        self.durations: list[float] = []

    class _StepCtx:
        def __init__(self, wd, idx):
            self.wd, self.idx = wd, idx

        def __enter__(self):
            self.t0 = time.time()
            self.fired = False
            self.timer = threading.Timer(self.wd.deadline_s, self._fire)
            self.timer.daemon = True
            self.timer.start()
            return self

        def _fire(self):
            self.fired = True
            self.wd.overruns.append((self.idx, time.time() - self.t0))
            if self.wd.on_straggler:
                self.wd.on_straggler(self.idx)

        def __exit__(self, *exc):
            self.timer.cancel()
            self.wd.durations.append(time.time() - self.t0)
            return False

    def step(self, idx: int):
        return self._StepCtx(self, idx)

    def stats(self) -> dict:
        d = self.durations
        return {
            "steps": len(d),
            "mean_s": sum(d) / len(d) if d else 0.0,
            "max_s": max(d) if d else 0.0,
            "overruns": len(self.overruns),
        }


class FailureInjector:
    """Deterministic failure injection for restart tests: raises
    ``SimulatedFailure`` at the configured step."""

    def __init__(self, fail_at_step: int | None = None):
        self.fail_at_step = fail_at_step

    def maybe_fail(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise SimulatedFailure(f"injected failure at step {step}")


class SimulatedFailure(RuntimeError):
    pass


def run_with_restarts(make_trainer, max_restarts: int = 3):
    """Supervisor loop: (re)build the trainer from the latest checkpoint and
    run until completion, tolerating ``SimulatedFailure``s."""
    attempts = 0
    while True:
        try:
            return make_trainer()
        except SimulatedFailure:
            attempts += 1
            if attempts > max_restarts:
                raise
