"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematically transparent version of its kernel; the
per-kernel tests sweep shapes/dtypes and ``assert_allclose`` kernel output
against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lorenzo3d_fwd_ref(x: jax.Array, eb: float) -> tuple[jax.Array, jax.Array]:
    """Prequant + separable 3-D Lorenzo delta (zero boundary)."""
    step = 2.0 * float(eb)
    q = jnp.round(x * (1.0 / step)).astype(jnp.int32)
    d = q
    for axis in range(3):
        zero = jnp.zeros_like(jax.lax.slice_in_dim(d, 0, 1, axis=axis))
        prev = jax.lax.slice_in_dim(d, 0, d.shape[axis] - 1, axis=axis)
        d = d - jnp.concatenate([zero, prev], axis=axis)
    rec = (q.astype(x.dtype) * step).astype(x.dtype)
    return d, rec


def lorenzo3d_inv_ref(d: jax.Array) -> jax.Array:
    q = d
    for axis in range(3):
        q = jnp.cumsum(q, axis=axis, dtype=jnp.int32)
    return q


def fused_enhance_ref(z, decomp, orig, eb: float, *, regulated: bool = True,
                      strict: bool = True):
    if regulated:
        resid = (2.0 * jax.nn.sigmoid(z.astype(jnp.float32)) - 1.0) * eb
    else:
        resid = z.astype(jnp.float32) * eb
    enh = (decomp.astype(jnp.float32) + resid).astype(decomp.dtype)
    bad = jnp.abs(enh.astype(jnp.float32) - orig.astype(jnp.float32)) > eb
    out = jnp.where(bad, decomp, enh) if strict else enh
    return out, bad.astype(jnp.uint8)


def conv2d3x3_ref(x, w, b, *, stride: int = 1, relu: bool = True):
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + b.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)
