"""Async archive writer: entry packing + codec + incremental save on a
writer thread.

The scheduler hands over *unpacked* per-field results (trained params,
normalization stats, the strict-mode outlier mask, the conventional
archive) the moment a group syncs; everything downstream — weight
flattening + codec compression (:func:`repro.core.archive.pack_weights`
via :func:`repro.core.neurlz.pack_entry`), outlier coordinate encoding,
msgpack packing and the append to the streaming container — runs on this
thread, fully overlapped with the next group's training.  The queue is
bounded so a slow disk back-pressures the pipeline instead of buffering
unbounded entries.

Entries are produced by the exact serial-engine packing helpers, so the
bytes that land in the container are bit-identical to the in-memory
engines' archive entries.

Failure semantics: a writer-thread error is **sticky** — every subsequent
``put``/``close`` re-raises it (chained to the original), ``close`` after
a failure *aborts* the container (no footer is ever written over a bad
byte stream) and the thread is always joined, never left draining
silently.  Writes to the container go through the fault layer: the
injection site ``"writer.add_entry"`` is probed per attempt, and when a
:class:`repro.faults.RetryPolicy` is configured a failed append rolls the
container back to the record boundary (:meth:`ArchiveAppender.rewind`)
before retrying, so a healed transient error leaves no torn bytes.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np

from .. import faults as faults_lib
from ..compressors import outliers as outlier_codec
from ..core import archive as arc_io
from ..core import neurlz
from ..obs import telemetry as obs_lib


@dataclasses.dataclass
class EntryTask:
    """One field's finished-but-unpacked compression result."""
    name: str
    conv_arc: dict
    params: object              # trained enhancer tree (host or device)
    stats: list
    aux: list[str]
    eb: float
    net_cfg: object
    history: list
    mask: np.ndarray | None     # strict-mode outlier mask (encoded here)
    mode: str | None = None     # per-field regulation-mode override
    #   (None -> the writer config's mode; set by mixed-bound runs so the
    #   packed entry records the mode the field actually honored)
    trace: tuple | None = None  # (vrange, n_points) when telemetry learning
    #   traces are on: the writer records the trajectory after packing, when
    #   the entry's actual base bytes are known
    degraded: str | None = None  # normalized degrade reason: the field's
    #   enhancer failed and the writer packs a conv-only entry instead
    #   (params/stats/mask are ignored)


@dataclasses.dataclass
class _RawEntry:
    """A pre-packed entry appended verbatim (the resume path re-appends
    salvaged entries through this, preserving per-entry bytes)."""
    name: str
    entry: dict


class AsyncArchiveWriter:
    """Bounded-queue writer thread over :class:`ArchiveAppender`.

    ``put`` blocks when ``queue_size`` entries are already pending (disk
    back-pressure).  ``close`` drains the queue, writes the index footer
    and returns writer statistics; a failure on the writer thread
    re-raises from every subsequent ``put``/``close`` (sticky), and a
    post-failure ``close`` aborts instead of sealing a bogus footer.

    Container knobs: ``version``/``durability``/``checksum``/``prelude``
    forward to :class:`ArchiveAppender` — v2 + a prelude makes a crashed
    run's partial container self-describing for salvage and resume.
    """

    _STOP = object()

    def __init__(self, sink, config, *, collect_stats: bool = True,
                 queue_size: int = 4, telemetry=None, faults=None,
                 version: int = 2, durability: str = "none",
                 checksum: str = "crc32", prelude: dict | None = None):
        self._appender = arc_io.ArchiveAppender(
            sink, version=version, durability=durability, checksum=checksum,
            prelude=prelude if version == 2 else None)
        self._config = config
        self._collect_stats = collect_stats
        self.tel = telemetry if telemetry is not None else obs_lib.NULL
        self.faults = faults if faults is not None else faults_lib.of(config)
        self._q: queue.Queue = queue.Queue(maxsize=max(1, queue_size))
        self._error: BaseException | None = None
        self._closed = False
        self.busy_s = 0.0
        self.put_wait_s = 0.0
        self.entries = 0
        self.degraded: list[str] = []
        self._thread = threading.Thread(target=self._run,
                                        name="neurlz-archive-writer",
                                        daemon=True)
        self._thread.start()

    def _pack(self, task: EntryTask) -> dict:
        cfg = neurlz.field_config(self._config, task.mode)
        if task.degraded is not None:
            self.degraded.append(task.name)
            self.tel.counter("faults.degraded").add()
            return neurlz.pack_degraded_entry(cfg, task.conv_arc, task.eb,
                                              task.degraded)
        entry = neurlz.pack_entry(
            cfg, task.conv_arc, task.params, task.stats, task.aux, task.eb,
            task.net_cfg, task.history, self._collect_stats)
        if task.mask is not None:
            entry["outliers"] = outlier_codec.encode_outliers(task.mask)
        if task.trace is not None:
            obs_lib.learning_trace(
                self.tel, task.name, task.history, eb=task.eb,
                vrange=task.trace[0],
                base_bytes=neurlz.entry_base_bytes(entry),
                n_points=task.trace[1], mode=cfg.mode)
        return entry

    def _write_entry(self, name: str, entry: dict) -> None:
        """Append under the fault layer: probe the injection site, and on a
        retryable failure rewind to the record boundary before the next
        attempt — a retried append never leaves torn bytes behind."""
        boundary = self._appender.bytes_written

        def attempt():
            self.faults.check("writer.add_entry")
            try:
                self._appender.add_entry(name, entry)
            except BaseException:
                self._appender.rewind(boundary)
                raise

        if self.faults.retry is None:
            attempt()
        else:
            faults_lib.retry_with_backoff(attempt, self.faults.retry,
                                          site="writer.add_entry",
                                          tel=self.tel)

    def _run(self) -> None:
        while True:
            task = self._q.get()
            try:
                if task is self._STOP:
                    return
                if self._error is not None:
                    continue        # drain after failure (puts never block)
                t0 = time.time()
                if isinstance(task, _RawEntry):
                    with self.tel.span("write", field=task.name):
                        self._write_entry(task.name, task.entry)
                else:
                    with self.tel.span("write", field=task.name):
                        self._write_entry(task.name, self._pack(task))
                self.tel.counter("writer.entries").add()
                self.tel.gauge("writer.queue_depth").set(self._q.qsize())
                self.busy_s += time.time() - t0
                self.entries += 1
            except BaseException as exc:  # noqa: BLE001 - reported to caller
                self._error = exc
            finally:
                self._q.task_done()

    def _check(self) -> None:
        # Sticky: the same failure re-raises from every later call, so the
        # caller's error path and a subsequent close() agree on the cause.
        if self._error is not None:
            raise RuntimeError("archive writer thread failed") from self._error

    @property
    def failed(self) -> bool:
        return self._error is not None

    def put(self, task: EntryTask) -> None:
        """Enqueue one entry; blocks under back-pressure (full queue).  The
        blocked time is writer work stalling compute, counted as
        non-overlapped in the stats."""
        self._check()
        if self._q.full():
            self.tel.counter("writer.backpressure_stalls").add()
        t0 = time.time()
        self._q.put(task)
        self.tel.gauge("writer.queue_depth").set(self._q.qsize())
        self.put_wait_s += time.time() - t0

    def put_entry(self, name: str, entry: dict) -> None:
        """Enqueue a pre-packed entry, appended verbatim (resume path)."""
        self._check()
        t0 = time.time()
        self._q.put(_RawEntry(name, entry))
        self.put_wait_s += time.time() - t0

    def drain(self) -> None:
        """Block until every queued entry is processed (the thread stays
        up), then surface any writer-thread failure."""
        self._q.join()
        self._check()

    def _shutdown(self) -> None:
        if not self._closed:
            self._closed = True
            self._q.put(self._STOP)
        self._thread.join()

    def close(self, meta: dict) -> dict:
        """Drain, seal the container, join the thread; returns stats.

        ``close_wait_s`` is the time the caller spent blocked here — writer
        work that did *not* overlap compute (the overlap metric in
        benchmarks is derived from it).  If the writer thread failed, the
        container is **aborted** (no footer over a bad byte stream — on v2
        the sealed entries stay salvageable) and the failure re-raises.
        """
        t0 = time.time()
        self._shutdown()
        if self._error is not None:
            self._appender.abort()
            self._check()
        total = self._appender.finalize(meta)
        return {
            "entries": self.entries,
            "bytes_written": total,
            "writer_busy_s": self.busy_s,
            "writer_put_wait_s": self.put_wait_s,
            "writer_close_wait_s": time.time() - t0,
            "degraded": list(self.degraded),
        }

    def abort(self) -> None:
        """Stop the thread without finalizing (error-path cleanup)."""
        if not self._closed:
            self._closed = True
            self._q.put(self._STOP)
        self._thread.join(timeout=10.0)
        self._appender.abort()