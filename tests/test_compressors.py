"""Round-trip + hard-error-bound tests for the conventional compressors."""
import numpy as np
import pytest

from repro import compressors as C


def smooth_field(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax)
    x /= max(np.abs(x).max(), 1e-9)
    return x.astype(dtype)


COMPRESSORS = ["szlike", "szlike-lorenzo", "zfplike"]


@pytest.mark.parametrize("comp", COMPRESSORS)
@pytest.mark.parametrize("rel_eb", [1e-2, 1e-3])
def test_roundtrip_bound_3d(comp, rel_eb):
    x = smooth_field((20, 24, 18))
    arc, rec = C.compress(x, rel_eb, compressor=comp)
    dec = C.decompress(arc)
    assert np.array_equal(rec, dec), "encoder rec must equal decoder output"
    err = np.abs(dec.astype(np.float64) - x.astype(np.float64)).max()
    assert err <= arc["abs_eb"]
    assert arc["nbytes"] < x.nbytes  # actually compresses


@pytest.mark.parametrize("comp", COMPRESSORS)
def test_roundtrip_2d(comp):
    x = smooth_field((37, 41))
    arc, rec = C.compress(x, 1e-3, compressor=comp)
    dec = C.decompress(arc)
    assert np.abs(dec.astype(np.float64) - x).max() <= arc["abs_eb"]


@pytest.mark.parametrize("comp", COMPRESSORS)
def test_fp64(comp):
    x = smooth_field((16, 20, 14), dtype=np.float64)
    arc, rec = C.compress(x, 1e-6, compressor=comp)
    dec = C.decompress(arc)
    assert dec.dtype == np.float64
    assert np.abs(dec - x).max() <= arc["abs_eb"]


def test_compression_ratio_ordering():
    """Looser bounds must compress better."""
    x = smooth_field((32, 32, 32))
    sizes = []
    for eb in (1e-2, 1e-3, 1e-4):
        arc, _ = C.compress(x, eb, compressor="szlike")
        sizes.append(arc["nbytes"])
    assert sizes[0] < sizes[1] < sizes[2]


def test_constant_field():
    x = np.full((8, 8, 8), 3.25, np.float32)
    arc, rec = C.compress(x, 1e-3, compressor="szlike")
    dec = C.decompress(arc)
    assert np.abs(dec - x).max() <= arc["abs_eb"]


def test_nan_handling():
    x = smooth_field((8, 10, 8))
    x[2, 3, 4] = np.nan
    arc, rec = C.compress(x, 1e-2, compressor="szlike")
    dec = C.decompress(arc)
    assert np.isnan(dec[2, 3, 4])
    finite = np.isfinite(x)
    assert np.abs(dec[finite] - x[finite]).max() <= arc["abs_eb"]
