"""Deterministic fault injection for the compression stack.

A :class:`FaultInjector` is a registry of *sites* — named points in the
pipeline (``"writer.add_entry"``, ``"train.temperature"``,
``"decode.entry"``) that call :meth:`FaultInjector.check` before doing
their work.  The injection *plan* maps a site to the zero-based invocation
indices at which the check raises :class:`InjectedFault`; everything is
counted, nothing is random, so a crash-recovery test replays bit-identically
across runs and engines.  Sites are matched exactly, or by prefix when the
plan key ends with ``"*"`` (``"train.*"`` hits every field's training).

This mirrors the seeded ``checkpoint.fault_tolerance.FailureInjector``
(step-indexed, raise-on-match) but generalizes it from one step counter to
a per-site registry, which is what a multi-site pipeline needs.
"""
from __future__ import annotations

import threading

__all__ = ["InjectedFault", "FaultInjector", "NULL_INJECTOR"]


class InjectedFault(RuntimeError):
    """Raised by :meth:`FaultInjector.check` when a site's plan fires."""

    def __init__(self, site: str, invocation: int):
        super().__init__(f"injected fault at site {site!r} "
                         f"(invocation {invocation})")
        self.site = site
        self.invocation = invocation


class FaultInjector:
    """Deterministic, thread-safe site/invocation fault registry.

    ``FaultInjector({"writer.add_entry": [1], "train.*": 0})`` raises on
    the second ``writer.add_entry`` check and the first check of any
    ``train.``-prefixed site.  ``hits`` records every (site, invocation)
    that fired; ``count(site)`` is the number of checks a site has seen —
    the accounting retry tests use to assert a transient fault was retried
    exactly once.
    """

    def __init__(self, plan: dict | None = None):
        self._plan: dict[str, set[int]] = {}
        for site, spec in (plan or {}).items():
            if isinstance(spec, int):
                spec = [spec]
            self._plan[site] = set(spec)
        self._counts: dict[str, int] = {}
        self.hits: list[tuple[str, int]] = []
        self._lock = threading.Lock()

    def _match(self, site: str) -> set[int] | None:
        spec = self._plan.get(site)
        if spec is not None:
            return spec
        for key, spec in self._plan.items():
            if key.endswith("*") and site.startswith(key[:-1]):
                return spec
        return None

    def check(self, site: str) -> None:
        """Count one invocation of ``site``; raise if the plan says so."""
        with self._lock:
            n = self._counts.get(site, 0)
            self._counts[site] = n + 1
            spec = self._match(site)
            fire = spec is not None and n in spec
            if fire:
                self.hits.append((site, n))
        if fire:
            raise InjectedFault(site, n)

    def count(self, site: str) -> int:
        """Checks seen by ``site`` so far (fired or not)."""
        with self._lock:
            return self._counts.get(site, 0)


class _NullInjector:
    """No-fault injector: ``check`` is a no-op (shared singleton)."""

    __slots__ = ()

    def check(self, site: str) -> None:
        return None

    def count(self, site: str) -> int:
        return 0


NULL_INJECTOR = _NullInjector()
