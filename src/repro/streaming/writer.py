"""Async archive writer: entry packing + codec + incremental save on a
writer thread.

The scheduler hands over *unpacked* per-field results (trained params,
normalization stats, the strict-mode outlier mask, the conventional
archive) the moment a group syncs; everything downstream — weight
flattening + codec compression (:func:`repro.core.archive.pack_weights`
via :func:`repro.core.neurlz.pack_entry`), outlier coordinate encoding,
msgpack packing and the append to the streaming container — runs on this
thread, fully overlapped with the next group's training.  The queue is
bounded so a slow disk back-pressures the pipeline instead of buffering
unbounded entries.

Entries are produced by the exact serial-engine packing helpers, so the
bytes that land in the container are bit-identical to the in-memory
engines' archive entries.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time

import numpy as np

from ..compressors import outliers as outlier_codec
from ..core import archive as arc_io
from ..core import neurlz
from ..obs import telemetry as obs_lib


@dataclasses.dataclass
class EntryTask:
    """One field's finished-but-unpacked compression result."""
    name: str
    conv_arc: dict
    params: object              # trained enhancer tree (host or device)
    stats: list
    aux: list[str]
    eb: float
    net_cfg: object
    history: list
    mask: np.ndarray | None     # strict-mode outlier mask (encoded here)
    mode: str | None = None     # per-field regulation-mode override
    #   (None -> the writer config's mode; set by mixed-bound runs so the
    #   packed entry records the mode the field actually honored)
    trace: tuple | None = None  # (vrange, n_points) when telemetry learning
    #   traces are on: the writer records the trajectory after packing, when
    #   the entry's actual base bytes are known


class AsyncArchiveWriter:
    """Bounded-queue writer thread over :class:`ArchiveAppender`.

    ``put`` blocks when ``queue_size`` entries are already pending (disk
    back-pressure).  ``close`` drains the queue, writes the index footer
    and returns writer statistics; a failure on the writer thread re-raises
    from the next ``put``/``close``.
    """

    _STOP = object()

    def __init__(self, sink, config, *, collect_stats: bool = True,
                 queue_size: int = 4, telemetry=None):
        self._appender = arc_io.ArchiveAppender(sink)
        self._config = config
        self._collect_stats = collect_stats
        self.tel = telemetry if telemetry is not None else obs_lib.NULL
        self._q: queue.Queue = queue.Queue(maxsize=max(1, queue_size))
        self._error: BaseException | None = None
        self.busy_s = 0.0
        self.put_wait_s = 0.0
        self.entries = 0
        self._thread = threading.Thread(target=self._run,
                                        name="neurlz-archive-writer",
                                        daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            task = self._q.get()
            try:
                if task is self._STOP:
                    return
                if self._error is not None:
                    continue        # drain after failure
                t0 = time.time()
                with self.tel.span("write", field=task.name):
                    cfg = neurlz.field_config(self._config, task.mode)
                    entry = neurlz.pack_entry(
                        cfg, task.conv_arc, task.params, task.stats,
                        task.aux, task.eb, task.net_cfg, task.history,
                        self._collect_stats)
                    if task.mask is not None:
                        entry["outliers"] = outlier_codec.encode_outliers(
                            task.mask)
                    self._appender.add_entry(task.name, entry)
                    if task.trace is not None:
                        obs_lib.learning_trace(
                            self.tel, task.name, task.history, eb=task.eb,
                            vrange=task.trace[0],
                            base_bytes=neurlz.entry_base_bytes(entry),
                            n_points=task.trace[1], mode=cfg.mode)
                self.tel.counter("writer.entries").add()
                self.tel.gauge("writer.queue_depth").set(self._q.qsize())
                self.busy_s += time.time() - t0
                self.entries += 1
            except BaseException as exc:  # noqa: BLE001 - reported to caller
                self._error = exc
            finally:
                self._q.task_done()

    def _check(self) -> None:
        if self._error is not None:
            exc, self._error = self._error, None
            raise RuntimeError("archive writer thread failed") from exc

    def put(self, task: EntryTask) -> None:
        """Enqueue one entry; blocks under back-pressure (full queue).  The
        blocked time is writer work stalling compute, counted as
        non-overlapped in the stats."""
        self._check()
        if self._q.full():
            self.tel.counter("writer.backpressure_stalls").add()
        t0 = time.time()
        self._q.put(task)
        self.tel.gauge("writer.queue_depth").set(self._q.qsize())
        self.put_wait_s += time.time() - t0

    def close(self, meta: dict) -> dict:
        """Drain, seal the container, join the thread; returns stats.

        ``close_wait_s`` is the time the caller spent blocked here — writer
        work that did *not* overlap compute (the overlap metric in
        benchmarks is derived from it).
        """
        t0 = time.time()
        self._q.put(self._STOP)
        self._thread.join()
        self._check()
        total = self._appender.finalize(meta)
        return {
            "entries": self.entries,
            "bytes_written": total,
            "writer_busy_s": self.busy_s,
            "writer_put_wait_s": self.put_wait_s,
            "writer_close_wait_s": time.time() - t0,
        }

    def abort(self) -> None:
        """Stop the thread without finalizing (error-path cleanup)."""
        self._q.put(self._STOP)
        self._thread.join(timeout=10.0)
        self._appender.abort()
