"""Property tests (hypothesis): streamed/chunked archives decode
bit-identically to ``engine="serial"`` across ragged field shapes and both
codecs (zlib always; zstd when the wheel is installed — the CI ``[zstd]``
matrix job runs these under both)."""
import io

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import core, streaming
from repro.compressors import codec
from repro.core import archive as A


# No function-scoped fixture here: @given runs many examples per test
# function, and hypothesis's function_scoped_fixture health check (rightly)
# rejects fixtures that would not reset between them.  The codec is forced
# and restored around each example body instead.
class _forced_codec:
    def __init__(self, name):
        if name == "zstd" and not codec.HAVE_ZSTD:
            pytest.skip("zstandard not installed")
        self._name = name

    def __enter__(self):
        codec.set_default_codec(self._name)
        return self._name

    def __exit__(self, *exc):
        codec.set_default_codec(None)


def _mk_snapshot(seed: int) -> dict[str, np.ndarray]:
    """2-4 fields with ragged slice counts; a second spatial signature and
    a float64 field show up for some seeds (multi-group plans)."""
    rng = np.random.default_rng(seed)
    n_fields = int(rng.integers(2, 5))
    out = {}
    for i in range(n_fields):
        hw = (12, 8) if (seed + i) % 3 == 0 else (8, 8)
        n = int(rng.integers(3, 7))
        x = np.cumsum(rng.standard_normal((n, *hw)), axis=0)
        out[f"f{i}"] = x.astype(np.float64 if (seed + i) % 4 == 0
                                else np.float32)
    return out


# Snapshots drawn from a seed keep the search space shape-bounded (few jit
# signatures) while hypothesis shrinks toward small failing seeds.
snapshots = st.integers(0, 10_000).map(_mk_snapshot)


@pytest.mark.parametrize("codec_name", ["zlib", "zstd"])
@settings(max_examples=6, deadline=None)
@given(snap=snapshots, eb=st.sampled_from([1e-2, 1e-3]))
def test_streamed_bit_identical_to_serial(codec_name, snap, eb):
    with _forced_codec(codec_name):
        cfg_serial = core.NeurLZConfig(epochs=1, mode="strict")
        cfg_stream = core.NeurLZConfig(epochs=1, mode="strict",
                                       engine="streaming", group_size=1)
        arc_serial = core.compress(snap, rel_eb=eb, config=cfg_serial)

        buf = io.BytesIO()
        streaming.compress(snap, buf, rel_eb=eb, config=cfg_stream)
        buf.seek(0)
        with A.ArchiveReader(buf) as r:
            arc_stream = core.assemble_streaming_archive(r)
        assert A.dumps(arc_stream["fields"]) == A.dumps(arc_serial["fields"])
        # the recorded codec is the forced one
        for e in arc_stream["fields"].values():
            assert e["weights"]["codec"] == codec_name

        buf.seek(0)
        dec_serial = core.decompress(arc_serial)
        for name, x in streaming.iter_decompress(buf):
            assert np.array_equal(x, dec_serial[name])


@pytest.mark.parametrize("codec_name", ["zlib", "zstd"])
@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_chunked_blocks_bit_identical_to_presplit_serial(codec_name, seed):
    with _forced_codec(codec_name):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(6, 12))
        big = np.cumsum(rng.standard_normal((n, 8, 8)),
                        axis=0).astype(np.float32)
        src = streaming.BlockedSource(streaming.DictSource({"huge": big}),
                                      max_block_bytes=big.nbytes // 2)
        cfg = core.NeurLZConfig(epochs=1, mode="strict", engine="streaming",
                                group_size=1)
        buf = io.BytesIO()
        streaming.compress(src, buf, 1e-3, config=cfg)

        man = src.manifest.get("huge")
        if man is None:                  # too small to split: passthrough
            presplit = {"huge": big}
        else:
            presplit = {bn: np.ascontiguousarray(big[lo:hi])
                        for bn, lo, hi in man["blocks"]}
        arc_serial = core.compress(presplit, rel_eb=1e-3,
                                   config=core.NeurLZConfig(epochs=1,
                                                            mode="strict"))
        buf.seek(0)
        with A.ArchiveReader(buf) as r:
            arc_stream = core.assemble_streaming_archive(r)
        assert A.dumps(arc_stream["fields"]) == A.dumps(arc_serial["fields"])

        buf.seek(0)
        dec = dict(streaming.iter_decompress(buf))
        assert list(dec) == ["huge"] and dec["huge"].shape == big.shape
        max_eb = max(e["abs_eb"] for e in arc_stream["fields"].values())
        err = np.abs(dec["huge"].astype(np.float64)
                     - big.astype(np.float64))
        assert float(err.max()) <= max_eb
