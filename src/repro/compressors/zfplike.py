"""Transform-based error-bounded lossy compressor (ZFP-style), in JAX.

Pipeline (Lindstrom, TVCG'14, adapted):
  1. partition the field into 4^d blocks (edge-padded),
  2. per-block block-floating-point: scale by 2^(P-2-emax) to int32,
  3. ZFP's exactly-invertible integer lifting transform along each axis,
  4. quantize coefficients by an arithmetic right-shift of ``b`` bits chosen
     from the error bound,
  5. zstd entropy stage over the coefficient planes (coefficient-major layout
     so same-statistics streams are adjacent),
  6. a sparse *correction pass*: any point whose reconstruction error would
     exceed ``eb`` gets an extra error-bounded correction code — this is how
     we keep ZFP's transform-domain rate while guaranteeing the pointwise
     bound exactly (ZFP's own fixed-accuracy mode is similarly conservative).

The transform is pure fixed-point slice arithmetic -> fully vectorized jnp
over all blocks at once (TPU-native: one fused elementwise program instead of
a per-block loop).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import entropy
from .szlike import _encode_mask, _decode_mask
from .quantize import abs_bound_from_rel

_P = 24  # fixed-point precision bits (int32 with transform headroom)


@dataclasses.dataclass(frozen=True)
class ZFPLikeConfig:
    zstd_level: int = 9
    eb_margin: float = 1e-9
    # Heuristic transform-gain guard when picking the shift width.
    gain_log2: int = 3


# ---------------------------------------------------------------------------
# ZFP integer lifting transform (exact fwd/inv pair), vectorized over blocks
# ---------------------------------------------------------------------------

def _fwd_lift(v: jnp.ndarray, axis: int) -> jnp.ndarray:
    """ZFP fwd_lift along an axis of length 4 (arithmetic shifts, int32)."""
    a = jnp.moveaxis(v, axis, 0)
    x, y, z, w = a[0], a[1], a[2], a[3]
    x = x + w; x = x >> 1; w = w - x
    z = z + y; z = z >> 1; y = y - z
    x = x + z; x = x >> 1; z = z - x
    w = w + y; w = w >> 1; y = y - w
    w = w + (y >> 1); y = y - (w >> 1)
    return jnp.moveaxis(jnp.stack([x, y, z, w]), 0, axis)


def _inv_lift(v: jnp.ndarray, axis: int) -> jnp.ndarray:
    a = jnp.moveaxis(v, axis, 0)
    x, y, z, w = a[0], a[1], a[2], a[3]
    y = y + (w >> 1); w = w - (y >> 1)
    y = y + w; w = w << 1; w = w - y
    z = z + x; x = x << 1; x = x - z
    y = y + z; z = z << 1; z = z - y
    w = w + x; x = x << 1; x = x - w
    return jnp.moveaxis(jnp.stack([x, y, z, w]), 0, axis)


def _blockify(x: np.ndarray) -> tuple[np.ndarray, tuple[int, ...], tuple[int, ...]]:
    """Pad to multiples of 4 and reshape to (nblocks, 4[,4[,4]])."""
    nd = x.ndim
    pads = [(0, (-d) % 4) for d in x.shape]
    xp = np.pad(x, pads, mode="edge")
    grid = tuple(d // 4 for d in xp.shape)
    if nd == 2:
        b = xp.reshape(grid[0], 4, grid[1], 4).transpose(0, 2, 1, 3)
        blocks = b.reshape(-1, 4, 4)
    else:
        b = xp.reshape(grid[0], 4, grid[1], 4, grid[2], 4).transpose(0, 2, 4, 1, 3, 5)
        blocks = b.reshape(-1, 4, 4, 4)
    return blocks, xp.shape, grid


def _unblockify(blocks: np.ndarray, pad_shape: tuple[int, ...], grid: tuple[int, ...],
                shape: tuple[int, ...]) -> np.ndarray:
    nd = len(shape)
    if nd == 2:
        b = blocks.reshape(grid[0], grid[1], 4, 4).transpose(0, 2, 1, 3)
    else:
        b = blocks.reshape(grid[0], grid[1], grid[2], 4, 4, 4).transpose(0, 3, 1, 4, 2, 5)
    return b.reshape(pad_shape)[tuple(slice(0, d) for d in shape)]


def _transform(blocks_i: jnp.ndarray, inverse: bool) -> jnp.ndarray:
    nd = blocks_i.ndim - 1
    axes = range(1, nd + 1)
    out = blocks_i
    if inverse:
        for ax in reversed(list(axes)):
            out = _inv_lift(out, ax)
    else:
        for ax in axes:
            out = _fwd_lift(out, ax)
    return out


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def compress(x: np.ndarray, rel_eb: float | None = None, *, abs_eb: float | None = None,
             config: ZFPLikeConfig = ZFPLikeConfig()) -> tuple[dict, np.ndarray]:
    x = np.asarray(x)
    if x.ndim not in (2, 3):
        raise ValueError(f"expected 2-D or 3-D field, got shape {x.shape}")
    orig_dtype = x.dtype
    if abs_eb is None:
        if rel_eb is None:
            raise ValueError("pass rel_eb or abs_eb")
        abs_eb = abs_bound_from_rel(x, rel_eb)
    eb = float(abs_eb) * (1.0 - config.eb_margin)

    work = np.nan_to_num(x.astype(np.float64), nan=0.0, posinf=0.0, neginf=0.0)
    nonfinite = ~np.isfinite(x.astype(np.float64))
    blocks, pad_shape, grid = _blockify(work)
    nb = blocks.shape[0]
    bdims = blocks.shape[1:]

    # Block floating point.
    amax = np.abs(blocks.reshape(nb, -1)).max(axis=1)
    emax = np.where(amax > 0, np.ceil(np.log2(np.maximum(amax, 1e-300))), -126).astype(np.int32)
    scale = np.exp2((_P - 2) - emax.astype(np.float64))
    bshape = (nb,) + (1,) * len(bdims)
    ints = np.clip(np.round(blocks * scale.reshape(bshape)), -(2**30), 2**30 - 1).astype(np.int32)

    coeff = np.asarray(_transform(jnp.asarray(ints), inverse=False))

    # Shift width from the bound: one ulp of the shifted coefficient maps to
    # ~2^(b+gain) / scale in value space; keep that below eb.
    with np.errstate(divide="ignore"):
        b_f = np.floor(np.log2(np.maximum(eb * scale, 1e-300))) - config.gain_log2
    bshift = np.clip(b_f, 0, 30).astype(np.int32)
    coeff_q = coeff >> bshift.reshape(bshape)

    # --- reconstruction (shared with decompress) ---
    rec = _reconstruct(coeff_q, bshift, emax, grid, pad_shape, tuple(work.shape), bdims)

    # Correction pass: enforce the pointwise bound exactly.
    err = work - rec
    need = np.abs(err) > eb
    corr_codes = np.round(err[need] / (2.0 * eb)).astype(np.int32)
    rec[need] = rec[need] + corr_codes * (2.0 * eb)
    # Literal escapes: non-finite points plus any point the output-dtype cast
    # would push past the bound (exactness for fp32 fields).
    cast_bad = np.abs(rec.astype(orig_dtype).astype(np.float64) - work) > eb
    lit_mask = nonfinite | cast_bad
    rec[lit_mask] = x.astype(np.float64)[lit_mask]

    arc = {
        "kind": "zfplike",
        "shape": list(work.shape), "pad_shape": list(pad_shape), "grid": list(grid),
        "dtype": str(orig_dtype), "abs_eb": float(abs_eb), "eb_int": eb,
        "emax": entropy.encode_codes(emax, config.zstd_level),
        "bshift": entropy.encode_codes(bshift, config.zstd_level),
        # Coefficient-major layout: same coefficient across blocks is adjacent.
        "coeff": entropy.encode_codes(
            np.moveaxis(coeff_q, 0, -1).reshape(-1, nb), config.zstd_level),
        "corr_mask": _encode_mask(need.ravel(), config.zstd_level),
        "corr_codes": entropy.encode_codes(corr_codes, config.zstd_level),
        "lit_mask": _encode_mask(lit_mask.ravel(), config.zstd_level),
        "lit_vals": entropy.encode_floats(
            np.asarray(x, dtype=np.float64)[lit_mask], config.zstd_level),
    }
    arc["nbytes"] = archive_nbytes(arc)
    return arc, rec.astype(orig_dtype, copy=False)


def compress_batched(xs, rel_eb: float | None = None, *,
                     abs_eb: float | None = None,
                     config: ZFPLikeConfig = ZFPLikeConfig()) -> list:
    """Compress a group of same-shape/same-dtype fields in one stacked pass.

    The conv-stage batched entry point.  All per-point stages here are
    elementwise numpy over the block axis, so the whole group's blocks are
    concatenated and pushed through ONE forward and ONE inverse lifting
    transform (exact int32 arithmetic — batching cannot change a bit);
    per-field error bounds ride along as a per-block vector.  Payloads are
    byte-identical to ``F`` independent :func:`compress` calls.
    """
    arrs = [np.asarray(x) for x in xs]
    if not arrs:
        return []
    shape, dtype = arrs[0].shape, arrs[0].dtype
    if any(a.shape != shape or a.dtype != dtype for a in arrs):
        raise ValueError("compress_batched needs same-shape/same-dtype fields")
    if arrs[0].ndim not in (2, 3):
        raise ValueError(f"expected 2-D or 3-D fields, got shape {shape}")
    if abs_eb is None and rel_eb is None:
        raise ValueError("pass rel_eb or abs_eb")

    nf = len(arrs)
    abs_ebs, ebs, works, nonfinites, blocks_per = [], [], [], [], []
    pad_shape = grid = None
    for a in arrs:
        ae = float(abs_eb) if abs_eb is not None else abs_bound_from_rel(a, rel_eb)
        abs_ebs.append(float(ae))
        ebs.append(float(ae) * (1.0 - config.eb_margin))
        w = np.nan_to_num(a.astype(np.float64), nan=0.0, posinf=0.0,
                          neginf=0.0)
        works.append(w)
        nonfinites.append(~np.isfinite(a.astype(np.float64)))
        blocks, pad_shape, grid = _blockify(w)
        blocks_per.append(blocks)
    nb = blocks_per[0].shape[0]
    bdims = blocks_per[0].shape[1:]

    # Per-block stages over the concatenated [F*nb, ...] block axis: same
    # elementwise numpy as the per-field path, with the per-field bound
    # repeated per block.
    blocks_all = np.concatenate(blocks_per, axis=0)
    n_all = nf * nb
    amax = np.abs(blocks_all.reshape(n_all, -1)).max(axis=1)
    emax = np.where(amax > 0, np.ceil(np.log2(np.maximum(amax, 1e-300))),
                    -126).astype(np.int32)
    scale = np.exp2((_P - 2) - emax.astype(np.float64))
    bshape = (n_all,) + (1,) * len(bdims)
    ints = np.clip(np.round(blocks_all * scale.reshape(bshape)),
                   -(2**30), 2**30 - 1).astype(np.int32)
    coeff = np.asarray(_transform(jnp.asarray(ints), inverse=False))
    eb_blocks = np.repeat(np.asarray(ebs, np.float64), nb)
    with np.errstate(divide="ignore"):
        b_f = np.floor(np.log2(np.maximum(eb_blocks * scale, 1e-300))) \
            - config.gain_log2
    bshift = np.clip(b_f, 0, 30).astype(np.int32)
    coeff_q = coeff >> bshift.reshape(bshape)
    coeff_dq = coeff_q << bshift.reshape(bshape)
    ints_rec = np.asarray(_transform(jnp.asarray(coeff_dq), inverse=True))
    blocks_rec = ints_rec.astype(np.float64) / scale.reshape(bshape)

    out = []
    for f in range(nf):
        sl = slice(f * nb, (f + 1) * nb)
        eb, work = ebs[f], works[f]
        rec = _unblockify(blocks_rec[sl], tuple(pad_shape), tuple(grid),
                          tuple(shape))
        err = work - rec
        need = np.abs(err) > eb
        corr_codes = np.round(err[need] / (2.0 * eb)).astype(np.int32)
        rec[need] = rec[need] + corr_codes * (2.0 * eb)
        cast_bad = np.abs(rec.astype(dtype).astype(np.float64) - work) > eb
        lit_mask = nonfinites[f] | cast_bad
        rec[lit_mask] = arrs[f].astype(np.float64)[lit_mask]
        arc = {
            "kind": "zfplike",
            "shape": list(shape), "pad_shape": list(pad_shape),
            "grid": list(grid),
            "dtype": str(dtype), "abs_eb": abs_ebs[f], "eb_int": eb,
            "emax": entropy.encode_codes(emax[sl], config.zstd_level),
            "bshift": entropy.encode_codes(bshift[sl], config.zstd_level),
            "coeff": entropy.encode_codes(
                np.moveaxis(coeff_q[sl], 0, -1).reshape(-1, nb),
                config.zstd_level),
            "corr_mask": _encode_mask(need.ravel(), config.zstd_level),
            "corr_codes": entropy.encode_codes(corr_codes, config.zstd_level),
            "lit_mask": _encode_mask(lit_mask.ravel(), config.zstd_level),
            "lit_vals": entropy.encode_floats(
                np.asarray(arrs[f], dtype=np.float64)[lit_mask],
                config.zstd_level),
        }
        arc["nbytes"] = archive_nbytes(arc)
        out.append((arc, rec.astype(dtype, copy=False)))
    return out


def _reconstruct(coeff_q, bshift, emax, grid, pad_shape, shape, bdims):
    nb = coeff_q.shape[0]
    bshape = (nb,) + (1,) * len(bdims)
    coeff_dq = coeff_q << bshift.reshape(bshape)
    ints_rec = np.asarray(_transform(jnp.asarray(coeff_dq), inverse=True))
    scale = np.exp2((_P - 2) - emax.astype(np.float64))
    blocks_rec = ints_rec.astype(np.float64) / scale.reshape(bshape)
    return _unblockify(blocks_rec, tuple(pad_shape), tuple(grid), tuple(shape))


def decompress(arc: dict) -> np.ndarray:
    if arc["kind"] != "zfplike":
        raise ValueError("not a zfplike archive")
    shape = tuple(arc["shape"])
    grid = tuple(arc["grid"])
    nb = int(np.prod(grid))
    nd = len(shape)
    bdims = (4,) * nd
    emax = entropy.decode_codes(arc["emax"]).ravel()
    bshift = entropy.decode_codes(arc["bshift"]).ravel()
    coeff_q = np.moveaxis(
        entropy.decode_codes(arc["coeff"]).reshape(bdims + (nb,)), -1, 0)
    rec = _reconstruct(coeff_q, bshift, emax, grid, arc["pad_shape"], shape, bdims)

    need = _decode_mask(arc["corr_mask"]).reshape(shape)
    corr = entropy.decode_codes(arc["corr_codes"]).ravel()
    rec[need] = rec[need] + corr * (2.0 * arc["eb_int"])
    nfm = _decode_mask(arc["lit_mask"]).reshape(shape)
    if nfm.any():
        rec[nfm] = entropy.decode_floats(arc["lit_vals"]).ravel()
    return rec.astype(np.dtype(arc["dtype"]), copy=False)


def decode_key(arc: dict) -> tuple:
    """Registry ``decode_key``: archives agreeing here share one stacked
    decode dispatch.  The per-field bound is excluded — corrections and
    literal escapes are applied per field after the shared transform."""
    return (tuple(arc["shape"]), arc["dtype"], tuple(arc["pad_shape"]),
            tuple(arc["grid"]))


def decompress_batched(arcs: list) -> list:
    """Decode a ``decode_key``-matched group in one stacked pass.

    Mirrors :func:`compress_batched`'s tail: all fields' blocks concatenate
    on the block axis and run through ONE inverse lifting transform (exact
    int32 arithmetic) plus one elementwise descale; the correction pass and
    literal patches stay per field.  Bit-identical to per-archive
    :func:`decompress`.
    """
    if not arcs:
        return []
    if any(a["kind"] != "zfplike" for a in arcs):
        raise ValueError("not zfplike archives")
    key = decode_key(arcs[0])
    if any(decode_key(a) != key for a in arcs):
        raise ValueError("decompress_batched needs decode_key-matched archives")
    shape = tuple(arcs[0]["shape"])
    grid = tuple(arcs[0]["grid"])
    nb = int(np.prod(grid))
    nd = len(shape)
    bdims = (4,) * nd

    emax = np.concatenate(
        [entropy.decode_codes(a["emax"]).ravel() for a in arcs])
    bshift = np.concatenate(
        [entropy.decode_codes(a["bshift"]).ravel() for a in arcs])
    coeff_q = np.concatenate(
        [np.moveaxis(entropy.decode_codes(a["coeff"]).reshape(bdims + (nb,)),
                     -1, 0) for a in arcs], axis=0)

    n_all = coeff_q.shape[0]
    bshape = (n_all,) + (1,) * nd
    coeff_dq = coeff_q << bshift.reshape(bshape)
    ints_rec = np.asarray(_transform(jnp.asarray(coeff_dq), inverse=True))
    scale = np.exp2((_P - 2) - emax.astype(np.float64))
    blocks_rec = ints_rec.astype(np.float64) / scale.reshape(bshape)

    out = []
    for f, arc in enumerate(arcs):
        rec = _unblockify(blocks_rec[f * nb:(f + 1) * nb],
                          tuple(arc["pad_shape"]), grid, shape)
        need = _decode_mask(arc["corr_mask"]).reshape(shape)
        corr = entropy.decode_codes(arc["corr_codes"]).ravel()
        rec[need] = rec[need] + corr * (2.0 * arc["eb_int"])
        nfm = _decode_mask(arc["lit_mask"]).reshape(shape)
        if nfm.any():
            rec[nfm] = entropy.decode_floats(arc["lit_vals"]).ravel()
        out.append(rec.astype(np.dtype(arc["dtype"]), copy=False))
    return out


def archive_nbytes(arc: dict) -> int:
    n = 64
    for key in ("emax", "bshift", "coeff", "corr_mask", "corr_codes",
                "lit_mask", "lit_vals"):
        if key in arc:
            n += arc[key]["nbytes"] + 16
    return n
