"""core/metrics edge cases + the zero-overhead telemetry disabled path.

Covers the degenerate inputs production snapshots actually contain —
constant fields (``vrange == 0``), fully non-finite fields — plus the
"disabled telemetry allocates nothing" contract: every no-op span/counter/
gauge handed out by :data:`repro.obs.NULL` is a shared singleton.
"""
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.core import metrics


# ---------------------------------------------------------------------------
# Constant field: vrange == 0 branch
# ---------------------------------------------------------------------------

def test_psnr_constant_field_uses_abs_value_range():
    o = np.full((4, 8, 8), 3.25, dtype=np.float32)
    r = o + np.float32(1e-3)
    p = metrics.psnr(o, r)
    assert np.isfinite(p)
    # vrange falls back to max(|3.25|, 1) = 3.25, mse = 1e-6
    assert p == pytest.approx(20 * np.log10(3.25) - 10 * np.log10(1e-6),
                              rel=1e-3)


def test_psnr_constant_zero_field_clamps_range_to_one():
    o = np.zeros((4, 8, 8), dtype=np.float32)
    p = metrics.psnr(o, o + np.float32(0.01))
    # vrange clamps to 1.0, so PSNR = -10·log10(1e-4) = 40 dB
    assert p == pytest.approx(40.0, rel=1e-3)


def test_psnr_exact_reconstruction_is_infinite():
    o = np.full((8, 8), 7.0)
    assert metrics.psnr(o, o.copy()) == float("inf")


def test_nrmse_constant_field_does_not_divide_by_zero():
    o = np.full((8, 8), 2.0)
    v = metrics.nrmse(o, o + 0.5)
    assert np.isfinite(v)


# ---------------------------------------------------------------------------
# All-NaN / non-finite fields
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn", [metrics.psnr, metrics.mae, metrics.nrmse])
def test_all_nan_field_returns_nan_not_crash(fn):
    o = np.full((4, 8, 8), np.nan, dtype=np.float32)
    r = np.zeros_like(o)
    assert np.isnan(fn(o, r))


@pytest.mark.parametrize("fn", [metrics.psnr, metrics.mae, metrics.nrmse])
def test_all_inf_field_returns_nan(fn):
    o = np.full((8, 8), np.inf)
    assert np.isnan(fn(o, np.zeros_like(o)))


def test_partial_nan_field_scores_finite_subset():
    rng = np.random.default_rng(0)
    o = rng.normal(size=(4, 8, 8))
    o[0] = np.nan
    r = o + 1e-4
    p = metrics.psnr(o, r)
    assert np.isfinite(p)
    # identical to scoring the finite subset directly
    assert p == pytest.approx(metrics.psnr(o[1:], r[1:]), rel=1e-9)


# ---------------------------------------------------------------------------
# Telemetry disabled path: shared no-op singletons, no per-call allocations
# ---------------------------------------------------------------------------

def test_null_telemetry_hands_out_shared_singletons():
    null = obs.NULL
    assert isinstance(null, obs.NullTelemetry)
    assert null.span("a") is null.span("b", field="x")
    assert null.counter("a") is null.counter("b")
    assert null.gauge("a") is null.gauge("b")
    # the no-op span context manager is itself the shared instance
    with null.span("work", n=1) as sp:
        assert sp is null.span("other")
        assert sp.set(more=2) is sp
    assert null.counter("c").add(5) is None
    assert null.gauge("g").set(1.0) is None
    assert null.spans == [] and null.counters == {} and null.traces == {}
    assert not null.enabled


def test_disabled_path_allocates_nothing_measurable():
    null = obs.NULL

    def hot_loop(n):
        for i in range(n):
            with null.span("step"):
                null.counter("hits").add()
                null.gauge("depth").set(i)

    hot_loop(10)                      # warm up any lazy caches
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    hot_loop(5000)
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    net = sum(st.size_diff for st in after.compare_to(before, "filename")
              if "telemetry" in st.traceback[0].filename)
    # shared singletons: the loop itself must not grow telemetry-owned memory
    assert net <= 512, f"disabled telemetry leaked {net} bytes over 5k spans"


def test_null_telemetry_is_default_for_plain_config():
    from repro.core import neurlz
    assert obs.of(neurlz.NeurLZConfig()) is obs.NULL
