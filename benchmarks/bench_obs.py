"""Observability rows: telemetry overhead guard + Perfetto trace artifact.

Two rows:

* ``obs/telemetry_overhead`` — the same batched-engine snapshot compressed
  with telemetry disabled and enabled (best-of-N wall-clock after a jit
  warmup).  The smoke profile **fails** when the enabled run exceeds the
  disabled one by more than 5% (plus a small absolute slack for scheduler
  noise) — the "zero-overhead-when-disabled, cheap-when-enabled" contract
  enforced in CI.
* ``obs/perfetto_trace`` — a telemetry-enabled *streaming* run exported as
  Chrome ``trace_event`` JSON (reader/writer threads overlapping compute),
  written to ``$BENCH_OBS_TRACE`` (default: tempdir) so CI can upload it as
  a workflow artifact.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

from . import common
from repro import core, obs
from repro.core import neurlz

# Enabled-vs-disabled guard: relative bound plus an absolute slack so a
# single scheduler hiccup on a ~1 s run cannot flake CI.
OVERHEAD_REL = 0.05
OVERHEAD_ABS_S = 0.1


def run(full: bool = False, smoke: bool = False) -> None:
    shape = (16, 32, 32) if full else (8, 16, 16)
    epochs = 4 if full else 2
    flds = common.snapshot_fields(3, shape=shape)

    cfg_off = core.NeurLZConfig(engine="batched", epochs=epochs)
    t_off, _ = common.timed_compress(flds, 1e-3, cfg_off)
    tel = obs.Telemetry()
    cfg_on = dataclasses.replace(cfg_off, telemetry=tel)
    t_on, _ = common.timed_compress(flds, 1e-3, cfg_on)
    overhead = (t_on - t_off) / t_off
    ok = t_on <= t_off * (1.0 + OVERHEAD_REL) + OVERHEAD_ABS_S
    common.csv_row(
        "obs/telemetry_overhead", t_on * 1e6,
        f"disabled_us={t_off * 1e6:.1f};overhead_pct={overhead * 100:.2f};"
        f"spans={len(tel.spans)};within_bound={ok}")
    if smoke and not ok:
        raise AssertionError(
            f"telemetry-enabled smoke run {t_on:.3f}s exceeds disabled "
            f"{t_off:.3f}s by more than {OVERHEAD_REL:.0%} "
            f"(+{OVERHEAD_ABS_S}s slack)")

    tel2 = obs.Telemetry()
    cfg_stream = core.NeurLZConfig(engine="streaming", epochs=epochs,
                                   telemetry=tel2)
    neurlz.compress_impl(flds, 1e-3, config=cfg_stream)
    out = os.environ.get(
        "BENCH_OBS_TRACE",
        os.path.join(tempfile.gettempdir(), "neurlz_trace.json"))
    nbytes = tel2.export_chrome_trace(out)
    events = tel2.chrome_trace()["traceEvents"]
    tids = {e["tid"] for e in events if e.get("ph") == "X"}
    common.csv_row(
        "obs/perfetto_trace", 0.0,
        f"path={out};bytes={nbytes};events={len(events)};threads={len(tids)}")
    assert len(tids) >= 2, "streaming trace should span multiple threads"
