"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/README of record:
EXPERIMENTS.md maps each prefix to the paper table/figure it reproduces).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

MODULES = [
    "bench_error_validation",   # Fig 11 / Fig 5
    "bench_rate_psnr",          # Fig 10
    "bench_bitrate_reduction",  # Table 2
    "bench_scalability",        # Table 3
    "bench_ablations",          # Fig 4
    "bench_training_evolution", # Figs 7/12/16
    "bench_regulation",         # Fig 13 / §5.1
    "bench_conflict",           # Fig 17 / §5.3
    "bench_grad_compress",      # framework integration (DESIGN.md §4)
    "bench_kernels",            # Pallas kernel validation
    "bench_roofline",           # §Roofline table from dry-run records
    "bench_streaming",          # bounded-memory pipeline vs in-memory engine
    "bench_obs",                # telemetry overhead guard + Perfetto trace
    "bench_durability",         # NLZSTRM2 checksum cost + salvage scan
    "bench_serving",            # serving tier: cache, coalesce, transcode
]


# CI smoke subset: the kernel validations plus the engine-comparison rows of
# the scalability bench and the streaming-budget row, at tiny-field settings
# (see each module's smoke path).
MODULES_SMOKE = [
    "bench_kernels",
    "bench_roofline",
    "bench_scalability",
    "bench_streaming",
    "bench_obs",
    "bench_durability",
    "bench_serving",
]

# Committed perf ledger (repo root): the smoke profile's machine-readable
# run record; scripts/perf_summary.py --compare diffs two of these and
# fails on >25% wall-clock regression.
LEDGER = "BENCH_PR10.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-field CI profile (fast, regression-only)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark module")
    ap.add_argument("--ledger", default=None, metavar="PATH",
                    help="write the run's rows as JSON here (--smoke "
                         f"defaults to <repo-root>/{LEDGER})")
    args = ap.parse_args()

    failures = 0
    ran = 0
    modules = MODULES_SMOKE if args.smoke else MODULES
    for name in modules:
        if args.only and args.only not in name:
            continue
        ran += 1
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            import inspect
            kwargs = {"full": args.full}
            if "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = args.smoke
            mod.run(**kwargs)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.only and ran == 0:
        print(f"# --only {args.only!r} matched no module in "
              f"{modules}", file=sys.stderr)
        sys.exit(2)
    ledger = args.ledger
    if ledger is None and args.smoke and not args.only:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        ledger = os.path.join(root, LEDGER)
    if ledger:
        from . import common
        with open(ledger, "w") as f:
            json.dump({"profile": "smoke" if args.smoke else
                       ("full" if args.full else "default"),
                       "modules": modules, "failures": failures,
                       "rows": common.ROWS}, f, indent=1, default=str)
            f.write("\n")
        print(f"# ledger -> {ledger} ({len(common.ROWS)} rows)", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
