"""Durability rows: NLZSTRM2 checksummed container cost vs NLZSTRM1.

Three rows:

* ``durability/stream_overhead`` — the same snapshot stream-compressed
  into a v1 (no checksums) and a v2 (sync markers + crc32 per record)
  container on disk, best-of-N wall-clock after a jit warmup.  The smoke
  profile **fails** when the v2 run exceeds v1 by more than 5% (plus a
  small absolute slack) — crash-safety must be noise against the real
  write path (training + codec dominate; the checksum is metadata).
* ``durability/append_overhead`` — the container layer alone (raw entry
  appends, no compression): the honest microcost of crc32 + record
  framing per byte, reported but not gated (page-cache-speed appends
  make any checksum look expensive; no real run is append-bound).
* ``durability/salvage_scan`` — full salvage scan
  (:func:`scan_container`) of a torn copy of the v2 container: the
  recovery cost a crashed run pays once at resume time.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from . import common
from repro import core
from repro.core import archive as A
from repro.streaming import pipeline

# v2-vs-v1 end-to-end guard: relative bound plus absolute slack so one
# scheduler hiccup on a ~1 s run cannot flake CI.
OVERHEAD_REL = 0.05
OVERHEAD_ABS_S = 0.1


def _stream_time(fields, path: str, version: int, cfg, reps: int) -> float:
    stream = pipeline.StreamConfig(container_version=version)
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        pipeline.compress(fields, path, 1e-3, config=cfg, stream=stream)
        best = min(best, time.time() - t0)
    return best


def _append_time(path: str, entries, version: int) -> float:
    t0 = time.time()
    app = A.ArchiveAppender(path, version=version)
    for name, entry in entries:
        app.add_entry(name, entry)
    app.finalize({"field_order": [n for n, _ in entries]})
    return time.time() - t0


def run(full: bool = False, smoke: bool = False) -> None:
    shape = (16, 32, 32) if full else (8, 16, 16)
    epochs = 4 if full else 2
    reps = 3
    fields = common.snapshot_fields(3, shape=shape)
    cfg = core.NeurLZConfig(engine="streaming", epochs=epochs)
    with tempfile.TemporaryDirectory() as d:
        p1, p2 = os.path.join(d, "v1.nlz"), os.path.join(d, "v2.nlz")
        _stream_time(fields, p2, 2, cfg, 1)          # jit warmup
        t1 = _stream_time(fields, p1, 1, cfg, reps)
        t2 = _stream_time(fields, p2, 2, cfg, reps)
        overhead = (t2 - t1) / t1
        ok = t2 <= t1 * (1.0 + OVERHEAD_REL) + OVERHEAD_ABS_S
        common.csv_row(
            "durability/stream_overhead", t2 * 1e6,
            f"v1_us={t1 * 1e6:.1f};overhead_pct={overhead * 100:.2f};"
            f"within_bound={ok}")
        if smoke and not ok:
            raise AssertionError(
                f"v2 checksummed stream-compress {t2:.3f}s exceeds v1 "
                f"{t1:.3f}s by more than {OVERHEAD_REL:.0%} "
                f"(+{OVERHEAD_ABS_S}s slack)")

        # container layer alone (informational: no real run is append-bound)
        n, payload = (64, 1 << 20) if full else (32, 1 << 18)
        rng = np.random.default_rng(0)
        entries = [(f"f{i}", {"conv": {"blob": rng.bytes(payload)}})
                   for i in range(n)]
        a1 = min(_append_time(p1, entries, 1) for _ in range(3))
        a2 = min(_append_time(p2, entries, 2) for _ in range(3))
        mb = n * payload / 1e6
        common.csv_row(
            "durability/append_overhead", a2 * 1e6,
            f"v1_us={a1 * 1e6:.1f};"
            f"overhead_pct={(a2 - a1) / a1 * 100:.2f};"
            f"mb_per_s={mb / a2:.0f};payload_mb={mb:.1f}")

        data = open(p2, "rb").read()
        torn = os.path.join(d, "torn.nlz")
        open(torn, "wb").write(data[: int(len(data) * 0.7)])
        t0 = time.time()
        scan = A.scan_container(torn)
        t_scan = time.time() - t0
        common.csv_row(
            "durability/salvage_scan", t_scan * 1e6,
            f"entries={len(scan['entries'])};of={n};"
            f"mb_scanned={len(data) * 0.7 / 1e6:.2f};"
            f"sealed={scan['sealed']}")
        assert not scan["sealed"] and scan["entries"]
