"""Sharding rules: divisibility guards, param/cache spec assignment."""
from types import SimpleNamespace

import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as sh
from repro.models import model as M

MESH = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})


def test_param_rules_qwen():
    cfg = configs.get_config("qwen3-4b")
    model = M.build_model(cfg, model_axis=16)
    abs_p = M.abstract_params(model)
    specs = sh.param_pspecs(abs_p, MESH)
    # embedding: vocab over model, d over data
    assert specs["embed"] == P("model", "data")
    # stacked layer weights: leading scan dim unsharded
    qspec = specs["layers"]["attn"]["w_q_in"]
    assert qspec == P(None, "data", "model")
    ospec = specs["layers"]["attn"]["w_o_out"]
    assert ospec == P(None, "model", "data")
    # 1-D norms replicated
    assert specs["layers"]["ln1"] == P()


def test_param_rules_moe_expert_parallel():
    cfg = configs.get_config("deepseek-moe-16b")
    model = M.build_model(cfg, model_axis=16)
    abs_p = M.abstract_params(model)
    specs = sh.param_pspecs(abs_p, MESH)
    up = specs["layers"]["moe"]["w_experts_up"]
    assert up == P(None, "model", "data", None)  # E over model = EP
    down = specs["layers"]["moe"]["w_experts_down"]
    assert down == P(None, "model", None, "data")


def test_divisibility_guard_drops_axis():
    # vocab 49155 (granite) does not divide 16 -> padded upstream, but the
    # guard itself must replicate odd dims rather than fail:
    spec = sh._guard(("model", "data"), (49155, 1536), MESH)
    assert spec == P(None, "data")


def test_batch_axes_for():
    assert sh.batch_axes_for(MESH, 256) == ("pod", "data")
    assert sh.batch_axes_for(MESH, 16) == ("data",)
    assert sh.batch_axes_for(MESH, 1) is None


def test_cache_rules_kv_fallback_to_head_dim():
    cfg = configs.get_config("qwen3-8b")  # kv=8: cannot shard over model=16
    model = M.build_model(cfg, model_axis=16)
    cache = M.abstract_cache(model, batch=128, max_len=1024)
    specs = sh.cache_pspecs(cache, MESH, batch_size=128)
    kspec = specs["layers"]["k"]
    # falls back to sharding head_dim over model
    assert kspec[-1] == "model"


def test_cache_rules_seq_parallel_when_batch_1():
    cfg = configs.get_config("zamba2-7b")
    model = M.build_model(cfg, model_axis=16)
    cache = M.abstract_cache(model, batch=1, max_len=2048)
    specs = sh.cache_pspecs(cache, MESH, batch_size=1)
    kspec = specs["attn"]["k"]
    assert "data" in kspec  # sequence dim sharded


def test_constrain_noop_without_mesh():
    sh.set_active_mesh(None)
    x = np.zeros((4, 4), np.float32)
    assert sh.constrain(x, ("batch", None)) is x
