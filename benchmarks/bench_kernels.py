"""Pallas kernel validation + host-side throughput of the fused pipelines
they replace (interpret-mode timing is meaningless; we time the jnp oracle
as the baseline and report the kernel's analytic HBM-traffic saving)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from repro.kernels import ops, ref


def _time(f, *args, n=5):
    f(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def run(full: bool = False):
    shape = (64, 128, 128) if full else (32, 64, 64)
    x = jnp.asarray(np.cumsum(
        np.random.default_rng(0).standard_normal(shape), 0), jnp.float32)
    eb = 1e-3

    us = _time(jax.jit(lambda a: ref.lorenzo3d_fwd_ref(a, eb)), x)
    d, rec = ops.lorenzo_quantize(x, eb)
    dr, rr = ref.lorenzo3d_fwd_ref(x, eb)
    ok = bool(jnp.array_equal(d, dr))
    # fused kernel: 1 read + 2 writes vs jnp: >=2 reads of q + extra traffic
    nbytes = x.size * 4
    common.csv_row("kernel/lorenzo3d_fwd", us,
                   f"match_ref={ok};fused_traffic_bytes={3*nbytes};"
                   f"unfused_traffic_bytes>={5*nbytes}")

    z = jnp.asarray(np.random.default_rng(1).standard_normal(shape), jnp.float32)
    dec = rec
    orig = x
    us = _time(jax.jit(lambda a, b, c: ref.fused_enhance_ref(a, b, c, eb)), z, dec, orig)
    out, mask = ops.enhance(z, dec, orig, eb)
    outr, maskr = ref.fused_enhance_ref(z, dec, orig, eb)
    ok = bool(jnp.allclose(out, outr, rtol=2e-5, atol=1e-6))
    common.csv_row("kernel/fused_enhance", us,
                   f"match_ref={ok};passes_fused=1;passes_unfused=4")

    xx = jnp.asarray(np.random.default_rng(2)
                     .standard_normal((8, 64, 64, 4)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(3)
                    .standard_normal((3, 3, 4, 8)) * 0.1, jnp.float32)
    b = jnp.zeros((8,), jnp.float32)
    us = _time(jax.jit(lambda a, ww, bb: ref.conv2d3x3_ref(a, ww, bb, stride=2)), xx, w, b)
    y = ops.conv3x3(xx, w, b, stride=2)
    yr = ref.conv2d3x3_ref(xx, w, b, stride=2)
    ok = bool(jnp.allclose(y, yr, atol=1e-5))
    common.csv_row("kernel/conv2d3x3_s2", us, f"match_ref={ok}")


if __name__ == "__main__":
    run()
