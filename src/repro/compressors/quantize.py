"""Error-bounded linear quantization primitives.

The contract shared by every compressor in this package:

    code = round((value - pred) / (2 * eb))            (int32)
    rec  = pred + code * (2 * eb)

which guarantees |rec - value| <= eb whenever |code| < CODE_CAP.  Points whose
code magnitude reaches CODE_CAP are *unpredictable*: the caller must store the
literal value and reconstruct it exactly (error 0).

Everything here is pure jnp so it can be jitted, vmapped and shard_mapped; the
Pallas kernels in ``repro.kernels`` fuse the same math for the hot paths and
are validated against these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Codes with |q| >= CODE_CAP are escaped to literals.  2^30 leaves headroom in
# int32 for the Lorenzo delta (sum of 8 codes) without overflow.
CODE_CAP = 1 << 15


def quantize(values: jax.Array, pred: jax.Array, eb: float) -> tuple[jax.Array, jax.Array]:
    """Quantize ``values`` against ``pred`` with absolute bound ``eb``.

    Returns ``(codes int32, unpredictable bool mask)``.  Where the mask is
    set the code is forced to 0 and the caller must store a literal.
    """
    step = 2.0 * eb
    q = jnp.round((values - pred) / step)
    unpred = jnp.abs(q) >= CODE_CAP
    # NaN/inf inputs are always literals.
    unpred = unpred | ~jnp.isfinite(values)
    codes = jnp.where(unpred, 0, q).astype(jnp.int32)
    return codes, unpred


def dequantize(codes: jax.Array, pred: jax.Array, eb: float) -> jax.Array:
    """Inverse of :func:`quantize` (literal positions must be patched after)."""
    step = jnp.asarray(2.0 * eb, dtype=pred.dtype)
    return pred + codes.astype(pred.dtype) * step


def quantize_reconstruct(
    values: jax.Array, pred: jax.Array, eb: float
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused quantize + dequantize returning ``(codes, rec, unpred)``.

    ``rec`` equals the literal value at unpredictable points, so the
    *compressor-side* reconstruction is exactly what the decompressor will
    produce after literal patching.  This single code path is what makes the
    codec deterministic: both sides run identical jnp arithmetic.
    """
    codes, unpred = quantize(values, pred, eb)
    rec = dequantize(codes, pred, eb)
    rec = jnp.where(unpred, values, rec)
    return codes, rec, unpred


def prequantize(values: jax.Array, eb: float) -> tuple[jax.Array, jax.Array]:
    """cuSZ-style pre-quantization: snap values onto the ``2*eb`` lattice.

    Returns ``(int32 lattice codes, unpred mask)``.  ``codes * 2eb`` is within
    ``eb`` of the input wherever ``unpred`` is False.
    """
    return quantize(values, jnp.zeros_like(values), eb)


def abs_bound_from_rel(x, rel_eb: float) -> float:
    """Value-range-relative bound -> absolute bound (SZ3 ``-M REL`` semantics)."""
    import numpy as np

    x = np.asarray(x)
    finite = x[np.isfinite(x)]
    if finite.size == 0:
        return float(rel_eb)
    vrange = float(finite.max() - finite.min())
    if vrange == 0.0:
        vrange = max(abs(float(finite.max())), 1.0)
    return float(rel_eb) * vrange
