"""Kernel-lowering dispatch: resolution, probes, fallbacks, and the archive
bit-stability contract (`lowering="jit"`/"auto" byte-identical to "eager"
for every engine and every compressor)."""
import dataclasses
import pickle
import warnings

import numpy as np
import pytest

from repro.core import batched_engine, conv_stage, neurlz, regulation
from repro.kernels import dispatch

warnings.simplefilter("ignore", DeprecationWarning)


# ---------------------------------------------------------------------------
# Dispatch mechanics
# ---------------------------------------------------------------------------

def test_resolve_rejects_unknown_lowering():
    with pytest.raises(ValueError, match="unknown lowering"):
        dispatch.resolve("dnn_forward", "fastest")


def test_resolve_rejects_unknown_op():
    with pytest.raises(KeyError, match="no registered eager reference"):
        dispatch.resolve("no_such_op", "eager")


def test_register_rejects_auto_as_variant():
    with pytest.raises(ValueError):
        dispatch.register("x", "auto", lambda: None)


def test_probe_failure_falls_back_and_is_recorded():
    calls = []
    dispatch.register("_test_op", "eager", lambda: "eager")
    dispatch.register("_test_op", "jit", lambda: "jit",
                      probe=lambda: calls.append(1) or False)
    try:
        fn, chosen = dispatch.resolve("_test_op", "jit")
        assert chosen == "eager" and fn() == "eager"
        assert ("_test_op", "jit", dispatch.backend(),
                "parity probe failed") in dispatch.fallbacks()
        # verdict is cached: a second resolve must not re-probe
        dispatch.resolve("_test_op", "jit")
        assert len(calls) == 1
    finally:
        dispatch._ops.pop("_test_op", None)
        dispatch.clear_cache()


def test_probe_exception_counts_as_failure():
    def boom():
        raise RuntimeError("cannot even run")

    dispatch.register("_test_op2", "eager", lambda: "eager")
    dispatch.register("_test_op2", "pallas", lambda: "pallas", probe=boom)
    try:
        fn, chosen = dispatch.resolve("_test_op2", "pallas")
        assert chosen == "eager"
    finally:
        dispatch._ops.pop("_test_op2", None)
        dispatch.clear_cache()


def test_auto_prefers_probe_passing_variant():
    dispatch.register("_test_op3", "eager", lambda: "eager")
    dispatch.register("_test_op3", "jit", lambda: "jit", probe=lambda: True)
    dispatch.register("_test_op3", "pallas", lambda: "pallas",
                      backends=("tpu",))
    try:
        _, chosen = dispatch.resolve("_test_op3", "auto")
        # pallas is TPU-gated -> jit wins on this box
        expect = "pallas" if dispatch.backend() == "tpu" else "jit"
        assert chosen == expect
    finally:
        dispatch._ops.pop("_test_op3", None)
        dispatch.clear_cache()


def test_backend_is_cached_and_forcible():
    b0 = dispatch.backend()
    with dispatch.force_backend("tpu"):
        assert dispatch.backend() == "tpu"
    assert dispatch.backend() == b0


def test_force_backend_drops_forced_verdicts():
    dispatch.register("_test_op4", "eager", lambda: "eager")
    dispatch.register("_test_op4", "jit", lambda: "jit", probe=lambda: True)
    try:
        with dispatch.force_backend("tpu"):
            dispatch.resolve("_test_op4", "jit")
            assert any(k[2] == "tpu" for k in dispatch._verdicts)
        assert not any(k[2] == "tpu" for k in dispatch._verdicts)
    finally:
        dispatch._ops.pop("_test_op4", None)
        dispatch.clear_cache()


def test_tpu_gated_variants_fall_back_on_cpu():
    if dispatch.backend() == "tpu":
        pytest.skip("CPU-only check")
    for op in ("dnn_forward", "lorenzo", "fused_enhance"):
        _, chosen = dispatch.resolve(op, "pallas")
        assert chosen == "eager", op
    assert any(f[0] == "dnn_forward" and f[1] == "pallas"
               for f in dispatch.fallbacks())


def test_parity_report_covers_all_ops():
    dispatch._register_all()
    report = dispatch.parity_report()
    assert {"dnn_forward", "lorenzo", "fused_enhance"} <= set(report)
    for rows in report.values():
        assert set(rows) == {"jit", "pallas"}


# ---------------------------------------------------------------------------
# Per-op parity on this backend
# ---------------------------------------------------------------------------

def test_lorenzo_jit_passes_parity_probe():
    from repro.compressors import szlike
    assert szlike._lorenzo_jit_probe()
    _, chosen = dispatch.resolve("lorenzo", "jit")
    assert chosen == "jit"


def test_fused_enhance_jit_passes_parity_probe():
    # x64 is enabled package-wide, so the jnp float64 mirror (with its FMA
    # barrier) is byte-identical to the numpy eager reference.
    assert regulation._probe_variant(regulation._fused_enhance_jit)
    _, chosen = dispatch.resolve("fused_enhance", "jit")
    assert chosen == "jit"


def test_fused_enhance_lowered_bytes_match_eager():
    d, r, o, eb = regulation._enhance_canaries()
    for mode in ("strict", "relaxed", "unregulated"):
        for low in ("eager", "jit", "auto"):
            rec, mask = regulation.enhance_lowered(
                d, r, o, eb, out_dtype=np.float32, mode=mode, lowering=low)
            rec0, mask0 = regulation.fused_enhance(
                d, r, o, eb, out_dtype=np.float32, mode=mode)
            assert rec.tobytes() == rec0.tobytes(), (mode, low)
            assert (mask is None) == (mask0 is None)
            if mask is not None:
                assert mask.tobytes() == mask0.tobytes()


# ---------------------------------------------------------------------------
# ConvStage lowering passthrough
# ---------------------------------------------------------------------------

def test_accepts_lowering_signature_inspection():
    assert conv_stage._accepts_lowering(lambda x, *, lowering="auto": x)
    assert conv_stage._accepts_lowering(lambda x, **kw: x)
    assert not conv_stage._accepts_lowering(lambda x, rel_eb: x)


@pytest.mark.parametrize("compressor", ["szlike", "szlike-lorenzo",
                                        "zfplike"])
def test_conv_stage_threads_lowering(compressor):
    rng = np.random.default_rng(0)
    fields = {f"f{i}": np.cumsum(
        rng.standard_normal((6, 8, 8)).astype(np.float32), axis=0)
        for i in range(2)}
    base = conv_stage.ConvStage(compressor, 1e-3, lowering="eager").run(fields)
    for low in ("jit", "auto"):
        stage = conv_stage.ConvStage(compressor, 1e-3, lowering=low)
        out = stage.run(fields)
        for n in fields:
            assert pickle.dumps(out[n][0]) == pickle.dumps(base[n][0]), \
                (compressor, low, n)
            assert out[n][1].tobytes() == base[n][1].tobytes()
        # szlike entries declare the kwarg; third-party-style zfplike doesn't
        if compressor == "zfplike":
            assert stage.stats.lowered_calls == 0
        else:
            assert stage.stats.lowered_calls == stage.stats.calls
        assert stage.stats.lowering == low


# ---------------------------------------------------------------------------
# field_batching="auto" resolution
# ---------------------------------------------------------------------------

def test_resolve_batching():
    assert batched_engine.resolve_batching("unroll", [4, 4]) == "unroll"
    assert batched_engine.resolve_batching("vmap", [4, 5]) == "vmap"
    assert batched_engine.resolve_batching("auto", [4, 4]) == "vmap"
    assert batched_engine.resolve_batching("auto", [4, 5]) == "unroll"
    assert batched_engine.resolve_batching("auto", [4]) == "unroll"


def test_unknown_field_batching_raises():
    rng = np.random.default_rng(1)
    fields = {"a": np.cumsum(
        rng.standard_normal((6, 8, 8)).astype(np.float32), axis=0)}
    cfg = neurlz.NeurLZConfig(engine="batched", epochs=1,
                              field_batching="wat")
    with pytest.raises(ValueError, match="field_batching"):
        neurlz.compress_impl(fields, 1e-3, config=cfg)


# ---------------------------------------------------------------------------
# The contract: archives are byte-identical across lowerings for every
# engine and every compressor.
# ---------------------------------------------------------------------------

def _fields(uniform=True):
    rng = np.random.default_rng(11)
    shapes = [(10, 10, 8)] * 2 if uniform else [(10, 10, 8), (13, 10, 8)]
    return {f"f{i}": np.cumsum(
        rng.standard_normal(s).astype(np.float32), axis=0)
        for i, s in enumerate(shapes)}


def _entries(fields, config, tmp_path=None):
    if config.engine == "streaming":
        from repro.streaming import pipeline
        arc = pipeline.compress_dict(fields, 1e-3, config=config,
                                     collect_stats=True)
    else:
        arc = neurlz.compress_impl(fields, 1e-3, config=config)
    return pickle.dumps(arc["fields"])


@pytest.mark.parametrize("engine", ["serial", "batched", "streaming"])
@pytest.mark.parametrize("compressor", ["szlike", "szlike-lorenzo",
                                        "zfplike"])
def test_archive_bytes_invariant_across_lowerings(engine, compressor):
    fields = _fields()
    base_cfg = neurlz.NeurLZConfig(engine=engine, compressor=compressor,
                                   epochs=2, group_size=0)
    want = _entries(fields, dataclasses.replace(base_cfg, lowering="eager"))
    for low in ("jit", "auto"):
        got = _entries(fields, dataclasses.replace(base_cfg, lowering=low))
        assert got == want, (engine, compressor, low)


def test_archive_bytes_invariant_ragged_groups():
    # Ragged slice counts force auto -> unroll; still byte-identical.
    fields = _fields(uniform=False)
    base_cfg = neurlz.NeurLZConfig(engine="batched", epochs=2, group_size=0)
    want = _entries(fields, dataclasses.replace(base_cfg, lowering="eager",
                                                field_batching="unroll"))
    got = _entries(fields, base_cfg)   # lowering=auto, field_batching=auto
    assert got == want


def test_auto_batching_bytes_match_serial():
    # Uniform groups under the auto default: whatever strategy the parity
    # probe admits, the archive must round-trip bit-exact against serial.
    fields = _fields(uniform=True)
    serial = _entries(fields, neurlz.NeurLZConfig(epochs=2))
    auto = _entries(fields, neurlz.NeurLZConfig(
        engine="batched", epochs=2, group_size=0))
    assert auto == serial


def test_explicit_vmap_bytes_match_serial_when_probe_passes():
    # Explicit vmap is best-effort max batching; the probe is the oracle
    # for whether this box's XLA lowers the stacked gradient identically
    # at this signature.
    fields = _fields(uniform=True)
    cfg = neurlz.NeurLZConfig(engine="batched", epochs=2, group_size=0,
                              field_batching="vmap")
    shape = next(iter(fields.values())).shape
    parity = batched_engine.vmap_bit_parity(
        cfg.net_config(1), shape[1:], min(cfg.batch, shape[0]),
        cfg.train_config())
    if not parity:
        pytest.skip("stacked gradient not bit-identical at this signature")
    serial = _entries(fields, neurlz.NeurLZConfig(epochs=2))
    assert _entries(fields, cfg) == serial


def test_vmap_parity_probe_is_cached():
    cfg = neurlz.NeurLZConfig()
    net = cfg.net_config(1)
    tcfg = cfg.train_config()
    v1 = batched_engine.vmap_bit_parity(net, (10, 8), 10, tcfg)
    key = ((10, 8), 1, 10, net.regulated, net.skip, tcfg.loss, tcfg.lowering)
    assert batched_engine._vmap_parity[key] == v1
    assert batched_engine.vmap_bit_parity(net, (10, 8), 10, tcfg) == v1


def test_decode_matches_across_lowerings():
    fields = _fields()
    cfg = neurlz.NeurLZConfig(epochs=2)
    arc = neurlz.compress_impl(fields, 1e-3, config=cfg)
    eager = neurlz.decompress_impl(arc)
    for engine in ("serial", "batched"):
        out = neurlz.decompress_impl(arc, engine=engine)
        for n in fields:
            assert out[n].tobytes() == eager[n].tobytes(), (engine, n)
