"""Public-API surface snapshot.

Locks ``repro.__all__`` plus the signatures of the session/archive surface
and the legacy dict shims, so an accidental rename, parameter drop or
default change fails CI instead of shipping silently.  Update the
snapshots *deliberately* when the API is meant to change.
"""
import inspect

import repro
from repro import api, core


def _sig(obj) -> str:
    return str(inspect.signature(obj))


def test_repro_all_snapshot():
    assert sorted(repro.__all__) == sorted([
        "NeurLZ", "Archive", "ErrorBound",
        "ModelConfig", "EngineConfig", "RegulationConfig",
        "NeurLZConfig", "Telemetry", "TelemetryConfig",
        "FaultConfig", "FaultInjector", "InjectedFault", "RetryPolicy",
        "CorruptArchiveError", "open", "ArchiveServer", "transcode",
    ])
    for name in repro.__all__:
        assert getattr(repro, name) is not None


SIGNATURES = {
    # session API
    "NeurLZ.__init__":
        "(self, model: 'ModelConfig | None' = None, "
        "engine: 'EngineConfig | None' = None, "
        "regulation: 'RegulationConfig | None' = None, *, "
        "config: 'NeurLZConfig | None' = None, **flat_kwargs)",
    "NeurLZ.compress":
        "(self, fields: 'Mapping', bounds=None, *, "
        "rel_eb: 'float | None' = None, abs_eb: 'float | None' = None, "
        "collect_stats: 'bool' = True) -> 'Archive'",
    "NeurLZ.compress_to":
        "(self, source, sink, bounds=None, *, "
        "rel_eb: 'float | None' = None, abs_eb: 'float | None' = None, "
        "collect_stats: 'bool' = True, resume: 'bool' = False) "
        "-> 'Archive'",
    "NeurLZ.decompress":
        "(self, archive, *, reassemble: 'bool' = False) -> 'dict'",
    # archive handle
    "Archive.open":
        "(source, *, repair: 'bool' = False) -> \"'Archive'\"",
    "Archive.verify": "(self) -> 'dict'",
    "Archive.decode": "(self, name: 'str', roi=None) -> 'np.ndarray'",
    "Archive.decode_all":
        "(self, *, engine: 'str' = 'serial', reassemble: 'bool' = False) "
        "-> 'dict[str, np.ndarray]'",
    "Archive.bitrate": "(self, name: 'str | None' = None) -> 'dict'",
    # ``path`` is untyped on purpose: accepts str or os.PathLike
    "Archive.save": "(self, path) -> 'int'",
    # bound spec
    "ErrorBound.__init__":
        "(self, rel: 'float | None' = None, abs: 'float | None' = None, "
        "mode: 'str | None' = None) -> None",
    # legacy dict shims (compat contract: these must not drift either)
    "core.compress":
        "(fields: 'Mapping[str, np.ndarray]', rel_eb: 'float | None' = None,"
        " *, abs_eb: 'float | None' = None, "
        "config: 'NeurLZConfig' = NeurLZConfig(compressor='szlike', "
        "mode='strict', epochs=100, batch=10, lr=0.01, seed=0, slice_axis=0,"
        " skip=True, learn_residual=True, cross_field={}, "
        "weight_dtype='float32', widths=(4, 4, 6, 6, 8), engine='serial', "
        "conv_batch=True, field_batching='auto', lowering='auto', "
        "group_size=2, "
        "prefetch=True, field_shard=True, max_resident_bytes=0, "
        "telemetry=None, faults=None), "
        "collect_stats: 'bool' = True, bounds=None) -> 'dict'",
    "core.decompress":
        "(arc, *, engine: 'str' = 'serial') -> 'dict[str, np.ndarray]'",
    "core.load": "(path: 'str')",
    "core.save": "(path: 'str', arc: 'dict') -> 'int'",
}


def test_signature_snapshot():
    objs = {
        "NeurLZ.__init__": repro.NeurLZ.__init__,
        "NeurLZ.compress": repro.NeurLZ.compress,
        "NeurLZ.compress_to": repro.NeurLZ.compress_to,
        "NeurLZ.decompress": repro.NeurLZ.decompress,
        "Archive.open": repro.Archive.open,
        "Archive.verify": repro.Archive.verify,
        "Archive.decode": repro.Archive.decode,
        "Archive.decode_all": repro.Archive.decode_all,
        "Archive.bitrate": repro.Archive.bitrate,
        "Archive.save": repro.Archive.save,
        "ErrorBound.__init__": repro.ErrorBound.__init__,
        "core.compress": core.compress,
        "core.decompress": core.decompress,
        "core.load": core.load,
        "core.save": core.save,
    }
    mismatches = {}
    for name, obj in objs.items():
        got = _sig(obj)
        if got != SIGNATURES[name]:
            mismatches[name] = got
    assert not mismatches, (
        "public API signature drift (update the snapshot deliberately):\n"
        + "\n".join(f"  {k}: {v}" for k, v in mismatches.items()))


def test_structured_configs_partition_flat_config():
    import dataclasses
    flat = {f.name for f in dataclasses.fields(core.NeurLZConfig)}
    split = [
        {f.name for f in dataclasses.fields(api.ModelConfig)},
        {f.name for f in dataclasses.fields(api.EngineConfig)},
        {f.name for f in dataclasses.fields(api.RegulationConfig)},
    ]
    union = set().union(*split)
    assert union == flat
    assert sum(len(s) for s in split) == len(union), "overlapping sub-configs"
