"""End-to-end NeurLZ: the paper's pipeline with all regulation modes."""
import numpy as np

from repro import core
from repro.core import metrics
from repro.data import fields as F

FIELDS = F.make_fields("nyx", shape=(24, 40, 40), seed=3)
SUB = {k: FIELDS[k] for k in ["temperature", "dark_matter_density"]}


def _compress(mode, cross=False, epochs=3, **kw):
    cfg = core.NeurLZConfig(
        epochs=epochs, mode=mode,
        cross_field={"temperature": ("dark_matter_density",)} if cross else {},
        **kw)
    return core.compress(SUB, rel_eb=1e-3, config=cfg)


def test_strict_mode_respects_1x_bound():
    arc = _compress("strict")
    dec = core.decompress(arc)
    for name, x in SUB.items():
        eb = arc["fields"][name]["abs_eb"]
        assert np.abs(dec[name].astype(np.float64) - x.astype(np.float64)).max() <= eb


def test_relaxed_mode_respects_2x_bound():
    arc = _compress("relaxed")
    dec = core.decompress(arc)
    for name, x in SUB.items():
        eb = arc["fields"][name]["abs_eb"]
        err = np.abs(dec[name].astype(np.float64) - x.astype(np.float64)).max()
        assert err <= 2 * eb
        assert "outliers" not in arc["fields"][name]  # no coord storage


def test_enhancement_never_worse_in_strict_mode():
    """Strict mode replaces bad points with decompressed values, so the max
    error can't exceed the conventional compressor's."""
    import repro.compressors as C

    arc = _compress("strict")
    dec = core.decompress(arc)
    for name, x in SUB.items():
        conv = C.decompress(arc["fields"][name]["conv"])
        p_conv = metrics.psnr(x, conv)
        p_enh = metrics.psnr(x, dec[name])
        assert p_enh >= p_conv - 0.5  # tolerance for tiny epochs


def test_cross_field_uses_aux_channels():
    arc = _compress("strict", cross=True)
    e = arc["fields"]["temperature"]
    assert e["aux"] == ["dark_matter_density"]
    assert e["net"]["c_in"] == 2
    dec = core.decompress(arc)
    eb = e["abs_eb"]
    assert np.abs(dec["temperature"].astype(np.float64)
                  - SUB["temperature"].astype(np.float64)).max() <= eb


def test_decode_is_deterministic():
    arc = _compress("strict")
    d1 = core.decompress(arc)
    d2 = core.decompress(arc)
    for k in d1:
        assert np.array_equal(d1[k], d2[k])


def test_bitrate_accounting_consistent():
    arc = _compress("strict")
    for name, x in SUB.items():
        br = arc["bitrate"][name]
        assert br["total_bytes"] == (br["conv_bytes"] + br["weight_bytes"]
                                     + br["outlier_bytes"])
        assert br["bitrate"] > 0
        # weights in the archive: ~3k params * 4B, zstd'd
        assert 4000 < br["weight_bytes"] < 16000


def test_archive_file_roundtrip(tmp_path):
    arc = _compress("strict")
    path = str(tmp_path / "block.nlz")
    nbytes = core.save(path, arc)
    assert nbytes > 0
    arc2 = core.load(path)
    d1, d2 = core.decompress(arc), core.decompress(arc2)
    for k in d1:
        assert np.array_equal(d1[k], d2[k])
