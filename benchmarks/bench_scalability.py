"""Paper Table 3: rate reduction and train/comp time ratios across block
sizes and training epochs (the scalability story) — plus the serial-vs-
batched engine comparison on multi-field snapshots.

The engine rows compress the same snapshot with ``engine="serial"`` (one
dispatch per epoch per field, host sync every epoch) and with
``engine="batched"`` (whole-group fused training dispatches, async
train/infer pipeline, conventional compression overlapped, field groups
spread over devices).  ``bit_identical=1`` asserts the two engines produced
byte-identical archives for the same config/seed.  Wall-clock speedups are
hardware-dependent: on a core-starved CI box both engines are bound by the
same total FLOPs and the ratio hovers near 1; the dispatch-count column is
the structural, hardware-independent win (the batched engine issues O(groups)
dispatches instead of O(fields x epochs) sync'd round trips).

The ``conv_stage/`` rows guard the shared conventional stage the same way:
every engine must compress a multi-field snapshot in fewer conv-stage
compressor calls than fields (same-(shape, dtype) groups run fused), and the
smoke profile fails outright on a regression to per-field dispatch.
"""
from __future__ import annotations

import os
import tempfile
import time

from . import common
from repro import compressors as C
from repro import core
from repro.core import archive as arc_io
from repro.core import neurlz
from repro.core.archive_api import Archive
from repro.data import fields as F


def _engine_rows(num_fields: int, shape, epoch_grid, repeats: int = 3):
    flds = common.snapshot_fields(num_fields, shape=shape)
    for epochs in epoch_grid:
        cfg_s = core.NeurLZConfig(epochs=epochs, mode="strict")
        cfg_b = core.NeurLZConfig(epochs=epochs, mode="strict",
                                  engine="batched", group_size=1)
        t_serial, arc_s = common.timed_compress(flds, 1e-3, cfg_s, repeats)
        t_batched, arc_b = common.timed_compress(flds, 1e-3, cfg_b, repeats)
        ident = int(arc_io.dumps(arc_s["fields"])
                    == arc_io.dumps(arc_b["fields"]))
        # Serial: one sync'd dispatch per field per epoch (+1 inference per
        # field); batched: one fused dispatch + one inference per group.
        d_serial = num_fields * (epochs + 1)
        d_batched = 2 * len(flds)  # group_size=1 -> one group per field
        conv_s = arc_s["timing"]["conv_stage"]
        conv_b = arc_b["timing"]["conv_stage"]
        common.csv_row(
            f"engine/fields{num_fields}/ep{epochs}",
            t_batched * 1e6,
            f"serial_s={t_serial:.3f};batched_s={t_batched:.3f};"
            f"speedup={t_serial / t_batched:.2f};bit_identical={ident};"
            f"dispatches_serial={d_serial};dispatches_batched={d_batched};"
            f"conv_calls_serial={conv_s['calls']};"
            f"conv_calls_batched={conv_b['calls']}")


def _conv_stage_guard(num_fields: int = 4, shape=(8, 16, 16)):
    """Dispatch-count regression guard for the shared conventional stage.

    Every engine compresses the same multi-field snapshot; the conv stage
    must batch same-(shape, dtype) fields, i.e. use strictly fewer
    compressor calls than fields.  A regression to per-field dispatch
    raises, which fails the smoke run.
    """
    flds = common.snapshot_fields(num_fields, shape=shape)
    for engine in ("serial", "batched", "streaming"):
        cfg = core.NeurLZConfig(epochs=1, mode="strict", engine=engine)
        t0 = time.time()
        arc = neurlz.compress_impl(flds, rel_eb=1e-3, config=cfg)
        st = arc["timing"]["conv_stage"]
        common.csv_row(
            f"conv_stage/{engine}/fields{num_fields}",
            (time.time() - t0) * 1e6,
            f"groups={st['groups']};calls={st['calls']};"
            f"batched_fields={st['batched_fields']};"
            f"fallback_fields={st['fallback_fields']};conv_s={st['conv_s']:.3f}")
        if st["calls"] >= st["fields"]:
            raise RuntimeError(
                f"conv-stage dispatch regression: engine={engine!r} used "
                f"{st['calls']} compressor calls for {st['fields']} fields "
                "(the batched conventional stage should need fewer)")


def _random_access_rows(num_fields: int = 4, shape=(8, 16, 16),
                        epochs: int = 1):
    """Single-field random-access decode latency vs full ``decompress``.

    The ``Archive`` handle's pitch is that decoding one field of a
    streaming container costs one entry's aux closure, not the snapshot.
    This row measures both paths against the same on-disk container and
    reports the entry-read accounting alongside wall clock, so a
    regression to eager whole-archive materialization shows up as
    ``entries_read`` jumping to ``num_fields``.
    """
    from repro.streaming import pipeline as streaming

    flds = common.snapshot_fields(num_fields, shape=shape)
    cfg = core.NeurLZConfig(epochs=epochs, mode="strict", engine="streaming")
    fd, path = tempfile.mkstemp(suffix=".nlzs")
    os.close(fd)
    try:
        streaming.compress(flds, path, rel_eb=1e-3, config=cfg)
        target = next(iter(flds))
        with Archive.open(path) as arc:     # warm the jit caches
            arc.decode(target)
        t0 = time.time()
        with Archive.open(path) as arc:
            arc.decode(target)
            reads = len(arc.reader.entry_reads)
        t_one = time.time() - t0
        t0 = time.time()
        full_dec = dict(streaming.iter_decompress(path))
        t_full = time.time() - t0
        common.csv_row(
            f"archive/random_access/fields{num_fields}",
            t_one * 1e6,
            f"one_field_s={t_one:.3f};full_s={t_full:.3f};"
            f"speedup={t_full / max(t_one, 1e-9):.2f};"
            f"entries_read={reads};fields={len(full_dec)}")
        if reads >= num_fields:
            raise RuntimeError(
                f"random-access decode regression: decoding one field read "
                f"{reads} entries of a {num_fields}-field container "
                "(lazy decode should read only the aux closure)")
    finally:
        os.unlink(path)


def run(full: bool = False, smoke: bool = False):
    if smoke:
        # CI regression profile: tiny fields, single epoch point; fails fast
        # if the engines diverge, the pipeline breaks, the conventional
        # stage regresses to per-field dispatch counts, or single-field
        # random access regresses to whole-archive decode.
        _engine_rows(4, (8, 16, 16), [1, 2], repeats=1)
        _conv_stage_guard(4, (8, 16, 16))
        _random_access_rows(4, (8, 16, 16))
        return

    sizes = [(16, 32, 32), (24, 40, 40), (32, 48, 48)]
    if full:
        sizes = [(32, 64, 64), (64, 64, 64), (64, 128, 128)]
    epoch_grid = [1, 5, 20] if not full else [1, 2, 5, 10]
    for shape in sizes:
        flds = F.make_fields("nyx", shape=shape, seed=2)
        x = flds["dark_matter_density"]
        C.compress(x, 1e-2, compressor="szlike")   # jit warmup
        t0 = time.time()
        arc_conv, _ = C.compress(x, 1e-2, compressor="szlike")
        conv_s = time.time() - t0
        curve = common.rd_curve(x, "szlike", [3e-2, 1e-2, 3e-3, 1e-3])
        for epochs in epoch_grid:
            t0 = time.time()
            arc, dec, out, t = common.run_neurlz({"f": x}, 1e-2,
                                                 mode="strict", epochs=epochs)
            r = out["f"]
            conv_eq = common.equal_psnr_bitrate(curve, r["psnr"])
            red = 100.0 * (1.0 - r["bitrate_amortized"] / conv_eq)
            common.csv_row(
                f"table3/size{shape[0]}x{shape[1]}x{shape[2]}/ep{epochs}",
                (time.time() - t0) * 1e6,
                f"rate_reduction_amortized_pct={red:.1f};"
                f"train_over_comp_pct={100 * arc['timing']['train_s'] / max(conv_s, 1e-9):.0f};"
                f"dec_s={t['decompress_s']:.2f}")

    # Multi-field engine comparison (the batched-engine acceptance rows).
    _engine_rows(4, (16, 32, 32), [1, 5, 20])
    _conv_stage_guard(4, (16, 32, 32))
    _random_access_rows(4, (16, 32, 32), epochs=2)
    if full:
        _engine_rows(8, (16, 32, 32), [1, 5])


if __name__ == "__main__":
    run()
