"""Pallas kernel validation + host-side throughput of the fused pipelines
they replace (interpret-mode timing is meaningless; we time the jnp oracle
as the baseline and report the kernel's analytic HBM-traffic saving)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from repro.kernels import ops, ref


def _time(f, *args, n=5):
    f(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / n * 1e6


def _time_best(f, *args, n=10, repeats=5):
    """Best-of-``repeats`` mean: robust same-machine comparison (used for
    the speedup gate, where a noisy shared runner must not flake CI)."""
    jax.block_until_ready(f(*args))  # warmup/compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(n):
            out = f(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / n * 1e6)
    return best


def _dnn_forward_row(full: bool, smoke: bool):
    """PR 9 hot-loop row: the bit-stable GEMM-tap forward vs the historical
    XLA-conv formulation (``forward_reference``, the PR 8 baseline path).
    Both are jitted and timed in-process, so the ratio is a same-machine
    comparison; the smoke profile **fails** below the 2x gate."""
    from repro.core import skipping_dnn as sd

    cfg = sd.SkippingDNNConfig(c_in=1)
    params = sd.init_params(jax.random.PRNGKey(0), cfg)
    shape = (10, 128, 128, 1) if full else (10, 64, 64, 1)
    x = jnp.asarray(np.random.default_rng(4).standard_normal(shape),
                    jnp.float32)
    ref_fn = jax.jit(lambda p, a: sd.forward_reference(p, a))
    fast_fn = jax.jit(lambda p, a: sd.forward(p, a, lowering="jit"))
    ref_us = _time_best(ref_fn, params, x)
    fast_us = _time_best(fast_fn, params, x)
    speedup = ref_us / fast_us
    close = bool(jnp.allclose(ref_fn(params, x), fast_fn(params, x),
                              atol=1e-5))
    common.csv_row("kernel/dnn_forward", fast_us,
                   f"ref_us={ref_us:.1f};speedup={speedup:.2f};"
                   f"min_speedup=2.0;match_ref={close}")
    if smoke and speedup < 2.0:
        raise AssertionError(
            f"skipping-DNN fast forward only {speedup:.2f}x over "
            f"forward_reference (gate: >= 2.0x at shape {shape})")


def run(full: bool = False, smoke: bool = False):
    shape = (64, 128, 128) if full else (32, 64, 64)
    x = jnp.asarray(np.cumsum(
        np.random.default_rng(0).standard_normal(shape), 0), jnp.float32)
    eb = 1e-3

    us = _time(jax.jit(lambda a: ref.lorenzo3d_fwd_ref(a, eb)), x)
    d, rec = ops.lorenzo_quantize(x, eb)
    dr, rr = ref.lorenzo3d_fwd_ref(x, eb)
    ok = bool(jnp.array_equal(d, dr))
    # fused kernel: 1 read + 2 writes vs jnp: >=2 reads of q + extra traffic
    nbytes = x.size * 4
    common.csv_row("kernel/lorenzo3d_fwd", us,
                   f"match_ref={ok};fused_traffic_bytes={3*nbytes};"
                   f"unfused_traffic_bytes>={5*nbytes}")

    z = jnp.asarray(np.random.default_rng(1).standard_normal(shape), jnp.float32)
    dec = rec
    orig = x
    us = _time(jax.jit(lambda a, b, c: ref.fused_enhance_ref(a, b, c, eb)), z, dec, orig)
    out, mask = ops.enhance(z, dec, orig, eb)
    outr, maskr = ref.fused_enhance_ref(z, dec, orig, eb)
    ok = bool(jnp.allclose(out, outr, rtol=2e-5, atol=1e-6))
    common.csv_row("kernel/fused_enhance", us,
                   f"match_ref={ok};passes_fused=1;passes_unfused=4")

    xx = jnp.asarray(np.random.default_rng(2)
                     .standard_normal((8, 64, 64, 4)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(3)
                    .standard_normal((3, 3, 4, 8)) * 0.1, jnp.float32)
    b = jnp.zeros((8,), jnp.float32)
    us = _time(jax.jit(lambda a, ww, bb: ref.conv2d3x3_ref(a, ww, bb, stride=2)), xx, w, b)
    y = ops.conv3x3(xx, w, b, stride=2)
    yr = ref.conv2d3x3_ref(xx, w, b, stride=2)
    ok = bool(jnp.allclose(y, yr, atol=1e-5))
    common.csv_row("kernel/conv2d3x3_s2", us, f"match_ref={ok}")

    _dnn_forward_row(full, smoke)


if __name__ == "__main__":
    run()
