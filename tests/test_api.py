"""First-class session API: NeurLZ sessions, structured configs, per-field
ErrorBound specs.

Covers the compat matrix (legacy dict calls and the session API produce
bit-identical archives across all three engines), mixed per-field bounds
(every field honors *its own* bound and mode — cross-engine bit-identical),
config split/join, and the bounds-resolution rules.
"""
import dataclasses

import numpy as np
import pytest

import repro
from repro import core
from repro.api import EngineConfig, join_config, split_config
from repro.core import archive as A
from repro.core.bounds import ErrorBound, resolve_bounds
from repro.data import fields as F

FIELDS = F.make_fields("nyx", shape=(8, 16, 16), seed=7)
NAMES = list(FIELDS)
ENGINES = ("serial", "batched", "streaming")


# ---------------------------------------------------------------------------
# Structured config <-> flat config
# ---------------------------------------------------------------------------

def test_config_split_join_roundtrip():
    flat = core.NeurLZConfig(compressor="zfplike", mode="relaxed", epochs=3,
                             engine="batched", group_size=1,
                             cross_field={"a": ("b",)}, widths=(4, 4))
    m, e, r = split_config(flat)
    assert join_config(m, e, r) == flat
    # the three sub-configs partition every flat field
    names = {f.name for f in dataclasses.fields(core.NeurLZConfig)}
    covered = {f.name for cfg in (m, e, r)
               for f in dataclasses.fields(cfg)}
    assert covered == names


def test_session_flat_kwargs_forwarded():
    sess = repro.NeurLZ(epochs=7, compressor="zfplike", mode="relaxed",
                        max_resident_bytes=123)
    assert sess.model.epochs == 7
    assert sess.engine.compressor == "zfplike"
    assert sess.engine.max_resident_bytes == 123
    assert sess.regulation.mode == "relaxed"
    assert sess.config == core.NeurLZConfig(
        epochs=7, compressor="zfplike", mode="relaxed",
        max_resident_bytes=123)
    with pytest.raises(TypeError, match="unknown NeurLZ config field"):
        repro.NeurLZ(not_a_field=1)


def test_engine_kwarg_accepts_flat_string():
    """Regression: ``engine`` names both the sub-config parameter and the
    flat NeurLZConfig field; a string must mean the flat field."""
    assert repro.NeurLZ(engine="batched").engine.engine == "batched"
    assert repro.NeurLZ().replace(engine="streaming").engine.engine \
        == "streaming"
    assert repro.NeurLZ(engine=EngineConfig(engine="serial")).engine.engine \
        == "serial"


def test_session_adopts_flat_config_and_replace():
    flat = core.NeurLZConfig(epochs=4, engine="batched")
    sess = repro.NeurLZ(config=flat)
    assert sess.config == flat
    sess2 = sess.replace(epochs=9)
    assert sess2.config == dataclasses.replace(flat, epochs=9)
    # explicit sub-config wins over the adopted flat config
    sess3 = repro.NeurLZ(config=flat, engine=EngineConfig(engine="serial"))
    assert sess3.engine.engine == "serial"
    assert sess3.model.epochs == 4


# ---------------------------------------------------------------------------
# ErrorBound resolution rules
# ---------------------------------------------------------------------------

def test_error_bound_validation():
    with pytest.raises(ValueError, match="unknown mode"):
        ErrorBound(rel=1e-3, mode="nope")
    with pytest.raises(ValueError, match="must be > 0"):
        ErrorBound(rel=-1.0)
    with pytest.raises(ValueError, match="rel= or abs="):
        ErrorBound().resolved("strict")
    assert ErrorBound(rel=1e-3).resolved("relaxed").mode == "relaxed"
    assert ErrorBound(rel=1e-3, mode="strict").resolved("relaxed").mode \
        == "strict"
    assert ErrorBound(abs=1.0, mode="relaxed").limit(1.0) == 2.0
    assert ErrorBound(abs=1.0, mode="unregulated").limit(1.0) == float("inf")


def test_resolve_bounds_rules():
    names = ["a", "b", "c"]
    r = resolve_bounds(names, None, 1e-3, None, default_mode="strict")
    assert all(r[n] == ErrorBound(rel=1e-3, mode="strict") for n in names)
    # bare numbers are relative bounds; missing names fall back
    r = resolve_bounds(names, {"a": 1e-2, "b": ErrorBound(abs=0.5)},
                       1e-3, None, default_mode="relaxed")
    assert r["a"] == ErrorBound(rel=1e-2, mode="relaxed")
    assert r["b"] == ErrorBound(abs=0.5, mode="relaxed")
    assert r["c"] == ErrorBound(rel=1e-3, mode="relaxed")
    with pytest.raises(KeyError, match="unknown fields"):
        resolve_bounds(names, {"zzz": 1e-3}, 1e-3, None)
    with pytest.raises(ValueError, match="no error bound"):
        resolve_bounds(names, {"a": 1e-3}, None, None)
    with pytest.raises(TypeError):
        resolve_bounds(names, object())


# ---------------------------------------------------------------------------
# API-compat matrix: legacy dict calls == session API, all engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_session_bit_identical_to_legacy_dict_api(engine):
    cfg = core.NeurLZConfig(epochs=2, mode="strict", engine=engine)
    with pytest.warns(DeprecationWarning) if _fresh_warn() else _nullctx():
        arc_old = core.compress(FIELDS, rel_eb=1e-3, config=cfg)
    sess = repro.NeurLZ(config=cfg)
    arc_new = sess.compress(FIELDS, rel_eb=1e-3)
    assert isinstance(arc_new, repro.Archive)
    assert A.dumps(arc_new["fields"]) == A.dumps(arc_old["fields"])
    assert arc_new["bitrate"] == arc_old["bitrate"]
    # decode parity: session decompress == legacy decompress
    dec_old = core.decompress(arc_old)
    dec_new = sess.decompress(arc_new)
    for n in FIELDS:
        assert np.array_equal(dec_old[n], dec_new[n])


def _fresh_warn():
    from repro.core import neurlz as _n
    return "compress" not in _n._warned_shims


class _nullctx:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


# ---------------------------------------------------------------------------
# Mixed per-field bounds
# ---------------------------------------------------------------------------

def _mixed_bounds():
    return {
        NAMES[0]: ErrorBound(rel=1e-3),                     # strict default
        NAMES[1]: ErrorBound(abs=2e-2, mode="relaxed"),
        NAMES[2]: ErrorBound(rel=1e-2, mode="unregulated"),
    }


def test_mixed_bounds_each_field_honors_its_own():
    bounds = _mixed_bounds()
    sess = repro.NeurLZ(epochs=2)
    arc = sess.compress(FIELDS, bounds=bounds, rel_eb=3e-3)
    dec = sess.decompress(arc)
    resolved = resolve_bounds(NAMES, bounds, 3e-3, None,
                              default_mode="strict")
    for n in NAMES:
        e = arc["fields"][n]
        assert e["mode"] == resolved[n].mode
        if resolved[n].abs is not None:
            assert e["abs_eb"] == pytest.approx(resolved[n].abs)
        err = float(np.abs(dec[n].astype(np.float64)
                           - FIELDS[n].astype(np.float64)).max())
        assert err <= resolved[n].limit(e["abs_eb"]) * (1 + 1e-9), n
    # the fallback field (not in the mapping) used rel_eb=3e-3, strict
    fb = NAMES[3]
    assert arc["fields"][fb]["mode"] == "strict"
    err = float(np.abs(dec[fb].astype(np.float64)
                       - FIELDS[fb].astype(np.float64)).max())
    assert err <= arc["fields"][fb]["abs_eb"] * (1 + 1e-9)


@pytest.mark.parametrize("engine", ("batched", "streaming"))
def test_mixed_bounds_cross_engine_bit_identical(engine):
    """Per-field bounds must not break the engines' bit-identity contract:
    mode-homogeneous groups + per-spec conv groups reproduce serial bits."""
    bounds = _mixed_bounds()
    ref = repro.NeurLZ(epochs=2).compress(FIELDS, bounds=bounds, rel_eb=3e-3)
    arc = repro.NeurLZ(epochs=2, engine=EngineConfig(engine=engine)) \
        .compress(FIELDS, bounds=bounds, rel_eb=3e-3)
    assert A.dumps(arc["fields"]) == A.dumps(ref["fields"])


def test_single_bound_spec_applies_to_all_fields():
    sess = repro.NeurLZ(epochs=1)
    arc = sess.compress(FIELDS, bounds=ErrorBound(rel=1e-3, mode="relaxed"))
    for n in NAMES:
        assert arc["fields"][n]["mode"] == "relaxed"
    # ...and is bit-identical to the same run via mode=relaxed + rel_eb
    ref = repro.NeurLZ(epochs=1, mode="relaxed").compress(FIELDS,
                                                          rel_eb=1e-3)
    assert A.dumps(arc["fields"]) == A.dumps(ref["fields"])


def test_conv_stage_groups_by_bound_spec():
    """Fields sharing a bound spec still batch through the fused entry;
    distinct specs split groups (the (shape, dtype, eb) planning unit)."""
    from repro.core import conv_stage
    flds = {f"f{i}": np.cumsum(np.ones((6, 8, 8), np.float32), axis=0) * i
            for i in range(4)}
    same = resolve_bounds(list(flds), ErrorBound(rel=1e-3), None, None)
    st = conv_stage.ConvStage("szlike", bounds=same)
    st.run(flds)
    assert st.stats.calls == 1 and st.stats.batched_fields == 4
    mixed = resolve_bounds(list(flds),
                           {"f0": 1e-3, "f1": 1e-3,
                            "f2": ErrorBound(abs=1e-2), "f3": 1e-2},
                           None, None)
    st = conv_stage.ConvStage("szlike", bounds=mixed)
    st.run(flds)
    assert st.stats.groups == 3            # {f0,f1}, {f2}, {f3}
    assert st.stats.batched_fields == 2
    assert st.stats.fallback_fields == 2


# ---------------------------------------------------------------------------
# Property: random mixed bounds, every field meets its own strict bound
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    _spec = st.builds(
        ErrorBound,
        rel=st.sampled_from([None, 1e-2, 1e-3]),
        abs=st.sampled_from([None, 5e-2]),
        mode=st.sampled_from([None, "strict", "relaxed"]),
    ).filter(lambda b: b.specified)

    @settings(max_examples=5, deadline=None)
    @given(specs=st.lists(_spec, min_size=2, max_size=4),
           default_mode=st.sampled_from(["strict", "relaxed"]))
    def test_property_mixed_bounds_all_honored(specs, default_mode):
        flds = {f"f{i}": FIELDS[NAMES[i % len(NAMES)]]
                for i in range(len(specs))}
        bounds = {f"f{i}": s for i, s in enumerate(specs)}
        sess = repro.NeurLZ(epochs=1, mode=default_mode)
        arc = sess.compress(flds, bounds=bounds)
        dec = sess.decompress(arc)
        resolved = resolve_bounds(list(flds), bounds, None, None,
                                  default_mode=default_mode)
        for n, x in flds.items():
            e = arc["fields"][n]
            assert e["mode"] == resolved[n].mode
            err = float(np.abs(dec[n].astype(np.float64)
                               - x.astype(np.float64)).max())
            assert err <= resolved[n].limit(e["abs_eb"]) * (1 + 1e-9), n
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_mixed_bounds_all_honored():
        pass
