"""Fused pre-quantization + 3-D Lorenzo delta — Pallas TPU kernel.

The compression hot loop (DESIGN.md §3): cuSZ's dual-quantization turns SZ's
sequential Lorenzo recurrence into a pure stencil, and this kernel fuses the
two memory-bound passes —

    q = round(x / (2*eb))          (prequant to the error-bound lattice)
    d = Δx Δy Δz q                 (8-point first-order Lorenzo delta)

— into a single HBM→VMEM pass, plus a fused reconstruction output
``rec = q * 2*eb`` (what the decompressor will see; NeurLZ trains against
it).  An unfused jnp pipeline writes q to HBM and re-reads it with shifted
gathers; at 512³ fp32 that is several× the traffic of this kernel.

Tiling: the grid walks z-slabs of ``tz`` planes; y/x stay at full extent in
VMEM (fields are ≤512² planes → ≤1 MB/plane fp32; pick ``tz`` so the slab
working set fits VMEM).  The one-plane z halo is satisfied by binding the
*same* input array a second time with a block-index map shifted by −1 —
no host-side padding copy; the kernel masks the z=0 boundary.

The inverse (``undelta``) is three prefix sums; the Pallas TPU grid is a
sequential loop, so a VMEM scratch plane carries the running z-sum across
slabs — a single pass over the data.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fwd_kernel(x_ref, xprev_ref, d_ref, rec_ref, *, inv_step: float, step: float):
    """One z-slab: prequant + separable Lorenzo delta (+ reconstruction)."""
    zi = pl.program_id(0)
    x = x_ref[...]
    q = jnp.round(x * inv_step).astype(jnp.int32)

    # z-neighbor plane: last plane of the previous slab's prequant (zero at z=0).
    qp_last = jnp.round(xprev_ref[...][-1:] * inv_step).astype(jnp.int32)
    qp_last = jnp.where(zi == 0, jnp.zeros_like(qp_last), qp_last)

    # Separable first differences Δz, Δy, Δx (their composition is the
    # 8-point Lorenzo stencil; order is irrelevant).
    d = q - jnp.concatenate([qp_last, q[:-1]], axis=0)
    d = d - jnp.concatenate([jnp.zeros_like(d[:, :1]), d[:, :-1]], axis=1)
    d = d - jnp.concatenate([jnp.zeros_like(d[:, :, :1]), d[:, :, :-1]], axis=2)

    d_ref[...] = d
    rec_ref[...] = (q.astype(x.dtype) * step).astype(x.dtype)


def _inv_kernel(d_ref, q_ref, carry_ref):
    """One z-slab of the inverse: cumsum x, y, then z with a carried plane."""
    zi = pl.program_id(0)

    @pl.when(zi == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    d = d_ref[...]
    s = jnp.cumsum(d, axis=2, dtype=jnp.int32)
    s = jnp.cumsum(s, axis=1, dtype=jnp.int32)
    s = jnp.cumsum(s, axis=0, dtype=jnp.int32)  # within-slab z prefix
    q = s + carry_ref[...]                      # broadcast carried plane
    q_ref[...] = q
    carry_ref[...] = q[-1]


@functools.partial(jax.jit, static_argnames=("eb", "tz", "interpret"))
def lorenzo3d_fwd(x: jax.Array, eb: float, *, tz: int = 8,
                  interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused prequant+delta.  ``x``: (D, H, W) float; returns (delta int32,
    rec same-dtype).  D must be divisible by ``tz`` (ops.py pads)."""
    dsz, h, w = x.shape
    assert dsz % tz == 0, (dsz, tz)
    step = 2.0 * float(eb)
    kernel = functools.partial(_fwd_kernel, inv_step=1.0 / step, step=step)
    return pl.pallas_call(
        kernel,
        grid=(dsz // tz,),
        in_specs=[
            pl.BlockSpec((tz, h, w), lambda i: (i, 0, 0)),
            # Same array, previous slab (clamped at 0; kernel masks z=0).
            pl.BlockSpec((tz, h, w), lambda i: (jnp.maximum(i - 1, 0), 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tz, h, w), lambda i: (i, 0, 0)),
            pl.BlockSpec((tz, h, w), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, jnp.int32),
            jax.ShapeDtypeStruct(x.shape, x.dtype),
        ],
        interpret=interpret,
    )(x, x)


@functools.partial(jax.jit, static_argnames=("tz", "interpret"))
def lorenzo3d_inv(d: jax.Array, *, tz: int = 8, interpret: bool = True) -> jax.Array:
    """Inverse delta: int32 lattice codes back from the delta stream."""
    dsz, h, w = d.shape
    assert dsz % tz == 0, (dsz, tz)
    return pl.pallas_call(
        _inv_kernel,
        grid=(dsz // tz,),
        in_specs=[pl.BlockSpec((tz, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tz, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(d.shape, jnp.int32),
        scratch_shapes=[pltpu.VMEM((h, w), jnp.int32)],
        interpret=interpret,
    )(d)
