"""Batched serving example: prefill a prompt batch, decode new tokens.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 32
"""
import argparse
from types import SimpleNamespace

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    serve(SimpleNamespace(arch=args.arch, batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen, seed=0))


if __name__ == "__main__":
    main()
