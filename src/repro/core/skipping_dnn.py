"""The paper's lightweight *skipping DNN* enhancer (§3.2.2, Fig. 8).

Ten conv layers — four stride-2 down-samplings, four stride-2 up-samplings
with skip-connection concatenations, plus input/output convs — totalling
~3,073 parameters at ``c_in=1`` (the paper reports "a 10-layer network
requires only 3,000 parameters").  Pure-JAX pytree params; the forward pass
is `jit`/`vmap`/`shard_map`-friendly so thousands of per-block enhancers can
train simultaneously across a pod (DESIGN.md §3, batched block training).

Output heads (§3.3.2, Fig. 6):
  * ``regulated``   — Sigmoid squashed to ``(2σ(z)−1) ∈ (−1, 1)``; since the
    residual target is normalized by the error bound, the enhanced value can
    exactly reach the original (balanced regulation, Case B) while the total
    error stays ≤ 2×eb.
  * ``unregulated`` — linear head, no bound (the paper's ablation).

``skip=False`` gives the non-skipping ablation of Fig. 4 (same depth).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

_DN = ("NHWC", "HWIO", "NHWC")


@dataclasses.dataclass(frozen=True)
class SkippingDNNConfig:
    c_in: int = 1                 # 1 = single-field, >1 = cross-field channels
    widths: tuple = (4, 4, 6, 6, 8)   # conv_in + four encoder stages
    regulated: bool = True
    skip: bool = True
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _conv_param(key, kh, kw, cin, cout, dtype):
    wkey, _ = jax.random.split(key)
    fan_in = kh * kw * cin
    # Note: float(...) keeps the He scale weakly typed (x64 mode would
    # otherwise promote the whole kernel to float64).
    w = jax.random.normal(wkey, (kh, kw, cin, cout), dtype) * float(np.sqrt(2.0 / fan_in))
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def init_params(key, cfg: SkippingDNNConfig):
    c0, c1, c2, c3, c4 = cfg.widths
    dt = cfg.jdtype
    keys = jax.random.split(key, 10)
    if cfg.skip:
        up_in = (c4, c3 + c3, c2 + c2, c1 + c1)  # after concat with encoder feature
        out_in = c1 + c0
    else:
        up_in = (c4, c3, c2, c1)
        out_in = c1
    return {
        "conv_in": _conv_param(keys[0], 3, 3, cfg.c_in, c0, dt),
        "down1": _conv_param(keys[1], 3, 3, c0, c1, dt),
        "down2": _conv_param(keys[2], 3, 3, c1, c2, dt),
        "down3": _conv_param(keys[3], 3, 3, c2, c3, dt),
        "down4": _conv_param(keys[4], 3, 3, c3, c4, dt),
        "up1": _conv_param(keys[5], 3, 3, up_in[0], c3, dt),
        "up2": _conv_param(keys[6], 3, 3, up_in[1], c2, dt),
        "up3": _conv_param(keys[7], 3, 3, up_in[2], c1, dt),
        "up4": _conv_param(keys[8], 3, 3, up_in[3], c1, dt),
        "conv_out": _conv_param(keys[9], 3, 3, out_in, 1, dt),
    }


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def stack_params(params_list):
    """Stack F same-structure enhancer trees into one tree with a leading
    field axis — the layout the batched engine trains under ``jax.vmap`` and
    shards across devices (``repro.distributed.sharding.field_sharding``)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def unstack_params(stacked, num_fields: int):
    """Inverse of :func:`stack_params`: per-field trees (views, no copy)."""
    return [jax.tree.map(lambda x, i=i: x[i], stacked)
            for i in range(num_fields)]


def _conv(x, p, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=_DN)
    return y + p["b"]


def _deconv(x, p):
    y = jax.lax.conv_transpose(
        x, p["w"], strides=(2, 2), padding="SAME", dimension_numbers=_DN)
    return y + p["b"]


@partial(jax.jit, static_argnames=("regulated", "skip"))
def forward(params, x, *, regulated: bool = True, skip: bool = True):
    """x: [N, H, W, C_in] normalized decompressed slices -> [N, H, W, 1]
    normalized residual prediction.  H, W are padded to multiples of 16
    internally (replicate edges) and cropped back."""
    n, h, w, _ = x.shape
    ph, pw = (-h) % 16, (-w) % 16
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (0, ph), (0, pw), (0, 0)), mode="edge")

    act = jax.nn.relu
    f0 = act(_conv(x, params["conv_in"]))          # H
    f1 = act(_conv(f0, params["down1"], stride=2))  # H/2
    f2 = act(_conv(f1, params["down2"], stride=2))  # H/4
    f3 = act(_conv(f2, params["down3"], stride=2))  # H/8
    f4 = act(_conv(f3, params["down4"], stride=2))  # H/16

    u = act(_deconv(f4, params["up1"]))             # H/8
    if skip:
        u = jnp.concatenate([u, f3], axis=-1)
    u = act(_deconv(u, params["up2"]))              # H/4
    if skip:
        u = jnp.concatenate([u, f2], axis=-1)
    u = act(_deconv(u, params["up3"]))              # H/2
    if skip:
        u = jnp.concatenate([u, f1], axis=-1)
    u = act(_deconv(u, params["up4"]))              # H
    if skip:
        u = jnp.concatenate([u, f0], axis=-1)
    z = _conv(u, params["conv_out"])                # [N,H,W,1]

    if regulated:
        out = 2.0 * jax.nn.sigmoid(z) - 1.0         # (−1, 1): balanced 2×eb regulation
    else:
        out = z
    if ph or pw:
        out = out[:, :h, :w, :]
    return out


def apply(params, x, cfg: SkippingDNNConfig):
    return forward(params, x, regulated=cfg.regulated, skip=cfg.skip)
