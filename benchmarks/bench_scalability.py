"""Paper Table 3: rate reduction and train/comp time ratios across block
sizes and training epochs (the scalability story)."""
from __future__ import annotations

import time

from . import common
from repro import compressors as C
from repro.core import metrics
from repro.data import fields as F


def run(full: bool = False):
    sizes = [(16, 32, 32), (24, 40, 40), (32, 48, 48)]
    if full:
        sizes = [(32, 64, 64), (64, 64, 64), (64, 128, 128)]
    epoch_grid = [1, 5, 20] if not full else [1, 2, 5, 10]
    for shape in sizes:
        flds = F.make_fields("nyx", shape=shape, seed=2)
        x = flds["dark_matter_density"]
        C.compress(x, 1e-2, compressor="szlike")   # jit warmup
        t0 = time.time()
        arc_conv, _ = C.compress(x, 1e-2, compressor="szlike")
        conv_s = time.time() - t0
        curve = common.rd_curve(x, "szlike", [3e-2, 1e-2, 3e-3, 1e-3])
        for epochs in epoch_grid:
            t0 = time.time()
            arc, dec, out, t = common.run_neurlz({"f": x}, 1e-2,
                                                 mode="strict", epochs=epochs)
            r = out["f"]
            conv_eq = common.equal_psnr_bitrate(curve, r["psnr"])
            red = 100.0 * (1.0 - r["bitrate_amortized"] / conv_eq)
            common.csv_row(
                f"table3/size{shape[0]}x{shape[1]}x{shape[2]}/ep{epochs}",
                (time.time() - t0) * 1e6,
                f"rate_reduction_amortized_pct={red:.1f};"
                f"train_over_comp_pct={100 * arc['timing']['train_s'] / max(conv_s, 1e-9):.0f};"
                f"dec_s={t['decompress_s']:.2f}")


if __name__ == "__main__":
    run()
