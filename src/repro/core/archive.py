"""NeurLZ archive serialization (paper Fig. 2 bottom: file format).

Layout per field: conventional compressed payload ‖ enhancer weights
(dataset-precision floats, zstd'd) ‖ outlier coordinates (strict mode) ‖
normalization stats + header.  msgpack binary container, numpy arrays as
typed blobs.  ``nbytes`` accounting matches what lands on disk.
"""
from __future__ import annotations

import io

import msgpack
import numpy as np

from ..compressors import codec


def _default(obj):
    if isinstance(obj, np.ndarray):
        return {b"__nd__": True, b"dtype": str(obj.dtype), b"shape": list(obj.shape),
                b"data": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj)}")


def _hook(obj):
    if b"__nd__" in obj:
        return np.frombuffer(obj[b"data"], dtype=obj[b"dtype"]).reshape(obj[b"shape"]).copy()
    return obj


def dumps(obj) -> bytes:
    return msgpack.packb(obj, default=_default, use_bin_type=True)


def loads(data: bytes):
    return msgpack.unpackb(data, object_hook=_hook, raw=False, strict_map_key=False)


def save(path: str, obj) -> int:
    data = dumps(obj)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load(path: str):
    with open(path, "rb") as f:
        return loads(f.read())


def pack_weights(params_tree, dtype: str = "float32") -> dict:
    """Flatten an enhancer param tree into one compressed blob (archive
    payload).  The codec name rides in the header so a zlib-only decoder can
    read archives written with zstd and vice versa."""
    import jax

    leaves, treedef = jax.tree.flatten(params_tree)
    arrs = [np.asarray(l, dtype=dtype) for l in leaves]
    buf = io.BytesIO()
    for a in arrs:
        buf.write(a.tobytes())
    payload, cname = codec.compress(buf.getvalue(), 9)
    return {
        "dtype": dtype,
        "shapes": [list(a.shape) for a in arrs],
        "payload": payload,
        "codec": cname,
        "nbytes": len(payload),
        "raw_nbytes": sum(a.nbytes for a in arrs),
        "n_params": sum(a.size for a in arrs),
    }


def unpack_weights(blob: dict, params_like) -> object:
    """Inverse of :func:`pack_weights`, restored into ``params_like`` tree."""
    import jax
    import jax.numpy as jnp

    raw = codec.decompress(blob["payload"], blob.get("codec", "zstd"))
    leaves, treedef = jax.tree.flatten(params_like)
    out, off = [], 0
    dt = np.dtype(blob["dtype"])
    for leaf, shape in zip(leaves, blob["shapes"]):
        n = int(np.prod(shape)) * dt.itemsize
        arr = np.frombuffer(raw[off:off + n], dtype=dt).reshape(shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
