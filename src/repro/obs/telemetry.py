"""Structured telemetry: spans, counters/gauges, per-field learning traces.

One :class:`Telemetry` handle rides through a whole compression (or decode)
run and records three kinds of data:

* **Spans** — nested wall/thread-time intervals (``with tel.span("conv")``).
  Nesting is tracked per thread; spans opened on a thread with no enclosing
  span (the streaming pipeline's reader and writer threads) attach to the
  run's root span, so the exported tree shows the async overlap instead of
  orphan intervals.
* **Counters / gauges** — monotonic totals (conv dispatches, archive entry
  reads, writer back-pressure stalls) and sampled levels (resident bytes
  vs. the ledger ceiling, writer queue depth).  Gauges keep a bounded
  timestamped sample trail so exporters can draw them as Perfetto counter
  tracks.
* **Learning traces** — per-field, per-epoch records of the online
  training trajectory (loss, residual RMS in original units, predicted
  PSNR/bitrate, optional measured PSNR on sampled slices): the paper's
  epoch-trajectory figures as first-class data instead of a thrown-away
  ``loss_history``.

The disabled path is allocation-free: a :data:`NULL` singleton implements
the same surface with shared no-op span/counter/gauge objects, so
``tel.span(...)`` / ``tel.counter(...).add()`` in a hot loop costs a method
call and nothing else.  Engines obtain their handle with :func:`of`, which
maps ``config.telemetry is None`` to :data:`NULL`.

This module deliberately imports neither jax nor any ``repro`` subpackage,
so constructing a :class:`Telemetry` never flips the x64 switch.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any

__all__ = [
    "Telemetry", "TelemetryConfig", "SpanRecord", "Counter", "Gauge",
    "NullTelemetry", "NULL", "of", "build_timing", "learning_trace",
    "TIMING_KEYS",
]


# Canonical engine timing schema: every engine's ``timing`` dict carries at
# least these keys (streaming adds its ledger/writer extras on top).
TIMING_KEYS = ("total_s", "conv_s", "train_s", "conv_stage")

# Crude per-outlier storage cost (bits) for the predicted-bitrate trace:
# the paper's B-bar coordinate is ~log2(n) bits; 32 covers every block size
# the benchmarks run.  A prediction, not an accounting — the archive's
# ``bitrate`` table stays the measured truth.
_PRED_OUTLIER_BITS = 32.0

_GAUGE_SAMPLE_CAP = 8192        # per-gauge timestamped sample trail bound


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Knobs for an enabled :class:`Telemetry` handle."""

    learning_traces: bool = True    # record per-epoch learning trajectories
    sample_psnr: bool = False       # measure PSNR on sampled slices per
    #   epoch (serial engine only — the batched/streaming engines run every
    #   epoch inside one fused dispatch, so there is no per-epoch host hook)
    sample_slices: int = 4          # slices sampled for sample_psnr
    max_spans: int = 200_000        # hard cap; further spans are dropped


@dataclasses.dataclass
class SpanRecord:
    """One finished span."""

    id: int
    parent: int | None
    name: str
    thread: int                 # python thread ident
    thread_name: str
    t0: float                   # seconds since the handle's epoch
    dur: float                  # wall seconds
    cpu: float                  # thread-CPU seconds inside the span
    attrs: dict[str, Any]


class Counter:
    """Monotonic counter (thread-safe adds)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def add(self, n: int | float = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self):
        return self._value


class Gauge:
    """Sampled level: keeps last/min/max plus a bounded (ts, value) trail
    so exporters can draw the gauge as a counter track over time."""

    __slots__ = ("name", "value", "vmin", "vmax", "samples", "_lock",
                 "_clock")

    def __init__(self, name: str, clock):
        self.name = name
        self.value = None
        self.vmin = None
        self.vmax = None
        self.samples: list[tuple[float, float]] = []
        self._lock = threading.Lock()
        self._clock = clock

    def set(self, v) -> None:
        with self._lock:
            self.value = v
            self.vmin = v if self.vmin is None else min(self.vmin, v)
            self.vmax = v if self.vmax is None else max(self.vmax, v)
            if len(self.samples) < _GAUGE_SAMPLE_CAP:
                self.samples.append((self._clock(), float(v)))


class _ActiveSpan:
    """Context manager for one open span; ``set(**attrs)`` adds attributes
    mid-flight (e.g. a result count known only at the end)."""

    __slots__ = ("_tel", "_name", "_attrs", "_id", "_parent", "_t0", "_cpu0",
                 "_root")

    def __init__(self, tel: "Telemetry", name: str, attrs: dict,
                 root: bool = False):
        self._tel = tel
        self._name = name
        self._attrs = attrs
        self._root = root

    def set(self, **attrs) -> "_ActiveSpan":
        self._attrs.update(attrs)
        return self

    def __enter__(self) -> "_ActiveSpan":
        tel = self._tel
        stack = tel._stack()
        self._parent = stack[-1] if stack else tel._root_id
        self._id = tel._next_id()
        if self._root and tel._root_id is None:
            tel._root_id = self._id
        stack.append(self._id)
        self._t0 = tel._clock()
        self._cpu0 = time.thread_time()
        return self

    def __exit__(self, *exc) -> bool:
        tel = self._tel
        dur = tel._clock() - self._t0
        cpu = time.thread_time() - self._cpu0
        stack = tel._stack()
        if stack and stack[-1] == self._id:
            stack.pop()
        th = threading.current_thread()
        tel._record(SpanRecord(
            id=self._id, parent=self._parent, name=self._name,
            thread=th.ident or 0, thread_name=th.name,
            t0=self._t0, dur=dur, cpu=cpu, attrs=self._attrs))
        if self._root and tel._root_id == self._id:
            tel._root_id = None
        return False


class Telemetry:
    """One run's telemetry sink.  Thread-safe; reusable across runs (spans
    and traces accumulate — hand a fresh handle per run for clean exports).
    """

    enabled = True

    def __init__(self, config: TelemetryConfig | None = None):
        self.config = config or TelemetryConfig()
        self.epoch = time.time()          # wall anchor for exported ts
        self._perf0 = time.perf_counter()
        self._spans: list[SpanRecord] = []
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._traces: dict[str, list[dict]] = {}
        self._lock = threading.Lock()
        self._ids = 0
        self._root_id: int | None = None
        self._local = threading.local()
        self.dropped_spans = 0

    # -- internals ----------------------------------------------------------

    def _clock(self) -> float:
        """Monotonic seconds since handle construction."""
        return time.perf_counter() - self._perf0

    def _stack(self) -> list[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        with self._lock:
            self._ids += 1
            return self._ids

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self._spans) >= self.config.max_spans:
                self.dropped_spans += 1
                return
            self._spans.append(rec)

    # -- recording surface --------------------------------------------------

    def span(self, name: str, *, root: bool = False, **attrs) -> _ActiveSpan:
        """Open a span (use as a context manager).  ``root=True`` marks the
        run's top-level span: spans later opened on *other* threads with no
        enclosing span (reader/writer threads) parent to it."""
        return _ActiveSpan(self, name, attrs, root=root)

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name, self._clock))
        return g

    def record_trace(self, field: str, record: dict) -> None:
        """Append one learning-trace record (one per training epoch)."""
        with self._lock:
            self._traces.setdefault(field, []).append(record)

    # -- read surface -------------------------------------------------------

    @property
    def spans(self) -> list[SpanRecord]:
        return list(self._spans)

    @property
    def counters(self) -> dict[str, int | float]:
        return {n: c.value for n, c in self._counters.items()}

    def counters_prefixed(self, prefix: str) -> dict[str, int | float]:
        """Counters whose name starts with ``prefix`` (e.g. ``"serve."``)
        — lets a subsystem report its own slice of a shared handle."""
        return {n: c.value for n, c in self._counters.items()
                if n.startswith(prefix)}

    @property
    def gauges(self) -> dict[str, dict]:
        return {n: {"last": g.value, "min": g.vmin, "max": g.vmax}
                for n, g in self._gauges.items()}

    def trace(self, field: str) -> list[dict]:
        return list(self._traces.get(field, ()))

    @property
    def traces(self) -> dict[str, list[dict]]:
        return {f: list(rs) for f, rs in self._traces.items()}

    def span_tree(self) -> dict[int | None, list[SpanRecord]]:
        """Finished spans grouped by parent id (children in start order)."""
        tree: dict[int | None, list[SpanRecord]] = {}
        for s in sorted(self._spans, key=lambda s: s.t0):
            tree.setdefault(s.parent, []).append(s)
        return tree

    def span_summary(self) -> dict[str, dict]:
        """Aggregate wall/CPU time per span name — the span-tree-derived
        timing schema engines attach to ``timing["spans"]``."""
        agg: dict[str, dict] = {}
        for s in self._spans:
            a = agg.setdefault(s.name, {"count": 0, "wall_s": 0.0,
                                        "cpu_s": 0.0})
            a["count"] += 1
            a["wall_s"] += s.dur
            a["cpu_s"] += s.cpu
        return agg

    def summary(self) -> dict:
        """Aggregated run summary (the third exporter)."""
        return {
            "spans": self.span_summary(),
            "counters": self.counters,
            "gauges": self.gauges,
            "fields": sorted(self._traces),
            "epochs": {f: len(rs) for f, rs in self._traces.items()},
            "dropped_spans": self.dropped_spans,
        }

    # -- export convenience (implementations in repro.obs.export) -----------

    def export_jsonl(self, sink) -> int:
        from . import export
        return export.write_jsonl(self, sink)

    def chrome_trace(self) -> dict:
        from . import export
        return export.chrome_trace(self)

    def export_chrome_trace(self, sink) -> int:
        from . import export
        return export.write_chrome_trace(self, sink)


# ---------------------------------------------------------------------------
# Disabled path: shared no-op singletons, zero allocations per call
# ---------------------------------------------------------------------------

class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


class _NullCounter:
    __slots__ = ()
    value = 0

    def add(self, n=1):
        return None


class _NullGauge:
    __slots__ = ()
    value = None
    vmin = None
    vmax = None

    def set(self, v):
        return None


_NULL_SPAN = _NullSpan()
_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()


class NullTelemetry:
    """Disabled telemetry: every call returns a shared no-op singleton."""

    enabled = False
    config = TelemetryConfig(learning_traces=False)

    def span(self, name, *, root=False, **attrs):
        return _NULL_SPAN

    def counter(self, name):
        return _NULL_COUNTER

    def gauge(self, name):
        return _NULL_GAUGE

    def record_trace(self, field, record):
        return None

    def trace(self, field):
        return []

    traces: dict = {}

    @property
    def spans(self):
        return []

    @property
    def counters(self):
        return {}

    def counters_prefixed(self, prefix):
        return {}

    @property
    def gauges(self):
        return {}

    def summary(self):
        return {}


NULL = NullTelemetry()


def of(config) -> Telemetry | NullTelemetry:
    """The telemetry handle carried by a config-like object (``.telemetry``
    attribute), or :data:`NULL`."""
    tel = getattr(config, "telemetry", None)
    return tel if tel is not None else NULL


# ---------------------------------------------------------------------------
# Engine timing schema + learning-trace recording
# ---------------------------------------------------------------------------

def build_timing(tel, *, total_s: float, conv_s: float, train_s: float,
                 conv_stage: dict, **extra) -> dict:
    """The one engine ``timing`` schema.

    Every engine reports the same core keys (:data:`TIMING_KEYS`); streaming
    passes its ledger/writer numbers through ``extra``.  With telemetry
    enabled the dict also carries ``spans`` — per-name wall/CPU aggregates
    derived from the span tree — so post-hoc consumers see where the wall
    clock went without holding the handle."""
    timing = {"total_s": total_s, "conv_s": conv_s, "train_s": train_s,
              "conv_stage": conv_stage}
    timing.update(extra)
    if tel.enabled:
        timing["spans"] = tel.span_summary()
    return timing


def learning_trace(tel, field: str, history, *, eb: float, vrange: float,
                   base_bytes: float, n_points: int, mode: str,
                   sample_psnr=None) -> None:
    """Record one field's per-epoch learning trajectory.

    ``history`` is the per-epoch mean training loss on the normalized
    residual ``(X − X')/eb`` — every engine produces it, fused or not.  From
    it and the run constants we derive, per epoch:

    * ``loss`` — the raw normalized-residual MSE (or L1) itself,
    * ``residual_rms`` — ``sqrt(loss) * eb``: RMS of the *remaining* error
      in original units had training stopped at this epoch,
    * ``pred_psnr`` — the PSNR that residual level implies against the
      field's value range,
    * ``pred_outlier_rate`` / ``pred_bitrate`` — a Gaussian-residual
      estimate of the strict-mode outlier fraction (``|r| > eb``) and the
      bitrate it would cost on top of the conv+weights base,
    * ``sample_psnr`` — measured PSNR on sampled slices when the serial
      engine ran with ``TelemetryConfig.sample_psnr`` (None elsewhere: the
      fused engines have no per-epoch host hook).
    """
    if not tel.enabled or not tel.config.learning_traces:
        return
    base_bitrate = 8.0 * float(base_bytes) / max(1, n_points)
    for e, loss in enumerate(history):
        loss = max(float(loss), 0.0)
        rms = math.sqrt(loss) * eb
        mse = loss * eb * eb
        if mse > 0.0 and vrange > 0.0:
            pred_psnr = (20.0 * math.log10(vrange)
                         - 10.0 * math.log10(mse))
        else:
            pred_psnr = float("inf")
        p_out = math.erfc(1.0 / math.sqrt(2.0 * loss)) if loss > 0.0 else 0.0
        rec = {
            "epoch": e,
            "loss": loss,
            "residual_rms": rms,
            "pred_psnr": pred_psnr,
            "pred_outlier_rate": p_out if mode == "strict" else 0.0,
            "pred_bitrate": base_bitrate + (_PRED_OUTLIER_BITS * p_out
                                            if mode == "strict" else 0.0),
        }
        if sample_psnr is not None and e < len(sample_psnr):
            rec["sample_psnr"] = float(sample_psnr[e])
        tel.record_trace(field, rec)
