"""Quality/rate metrics used throughout the evaluation (paper §4.1).

PSNR (value-range referenced, SDRBench convention), MAE, DSSIM (structural
dissimilarity averaged over slices), outlier rate, and the paper's bit-rate
formula: ``bitrate = (size(Z) + supplementary) / num_points`` in bits/value.
"""
from __future__ import annotations

import numpy as np


def psnr(orig: np.ndarray, rec: np.ndarray) -> float:
    o = np.asarray(orig, dtype=np.float64)
    r = np.asarray(rec, dtype=np.float64)
    finite = np.isfinite(o)
    o, r = o[finite], r[finite]
    if o.size == 0:             # all-NaN/Inf field: no reference values
        return float("nan")
    vrange = o.max() - o.min()
    if vrange == 0:
        vrange = max(abs(o.max()), 1.0)
    mse = np.mean((o - r) ** 2)
    if mse == 0:
        return float("inf")
    return float(20.0 * np.log10(vrange) - 10.0 * np.log10(mse))


def mae(orig: np.ndarray, rec: np.ndarray) -> float:
    o = np.asarray(orig, dtype=np.float64)
    r = np.asarray(rec, dtype=np.float64)
    finite = np.isfinite(o)
    if not finite.any():
        return float("nan")
    return float(np.mean(np.abs(o[finite] - r[finite])))


def nrmse(orig: np.ndarray, rec: np.ndarray) -> float:
    o = np.asarray(orig, dtype=np.float64)
    r = np.asarray(rec, dtype=np.float64)
    finite = np.isfinite(o)
    o, r = o[finite], r[finite]
    if o.size == 0:
        return float("nan")
    vrange = max(o.max() - o.min(), 1e-300)
    return float(np.sqrt(np.mean((o - r) ** 2)) / vrange)


def _ssim_2d(a: np.ndarray, b: np.ndarray, win: int = 7) -> float:
    """SSIM with a uniform window (box filter via cumsum — no scipy)."""
    a = a.astype(np.float64)
    b = b.astype(np.float64)
    rng = max(a.max() - a.min(), 1e-300)
    c1, c2 = (0.01 * rng) ** 2, (0.03 * rng) ** 2

    def boxmean(x):
        pad = win // 2
        xp = np.pad(x, pad, mode="edge")
        c = np.cumsum(np.cumsum(xp, 0), 1)
        c = np.pad(c, ((1, 0), (1, 0)))
        h, w = x.shape
        s = (c[win:win + h, win:win + w] - c[:h, win:win + w]
             - c[win:win + h, :w] + c[:h, :w])
        return s / (win * win)

    mu_a, mu_b = boxmean(a), boxmean(b)
    va = boxmean(a * a) - mu_a ** 2
    vb = boxmean(b * b) - mu_b ** 2
    cov = boxmean(a * b) - mu_a * mu_b
    ssim = ((2 * mu_a * mu_b + c1) * (2 * cov + c2)) / (
        (mu_a ** 2 + mu_b ** 2 + c1) * (va + vb + c2))
    return float(ssim.mean())


def dssim(orig: np.ndarray, rec: np.ndarray, slice_axis: int = 0,
          max_slices: int = 16) -> float:
    """Structural dissimilarity ``(1 − SSIM)/2`` averaged over sampled slices."""
    o = np.moveaxis(np.asarray(orig), slice_axis, 0)
    r = np.moveaxis(np.asarray(rec), slice_axis, 0)
    if o.ndim == 2:
        o, r = o[None], r[None]
    n = o.shape[0]
    idx = np.linspace(0, n - 1, min(n, max_slices)).astype(int)
    vals = [_ssim_2d(o[i], r[i]) for i in idx]
    return float((1.0 - np.mean(vals)) / 2.0)


def bitrate(total_bytes: float, num_points: int) -> float:
    """Average bits per value, the paper's comprehensive storage metric."""
    return 8.0 * float(total_bytes) / float(num_points)


def compression_ratio(orig_nbytes: int, total_bytes: float) -> float:
    return float(orig_nbytes) / float(total_bytes)


def bitrate_reduction(base_bitrate: float, new_bitrate: float) -> float:
    """Relative bit-rate reduction (%) at equal PSNR (paper Table 2)."""
    return 100.0 * (1.0 - new_bitrate / base_bitrate)
