"""Conventional error-bounded lossy compressors (the substrate NeurLZ enhances).

FP64 scientific data (Miranda) needs double-precision reconstruction, so the
compression stack runs with x64 enabled.  Model code always passes explicit
dtypes and is unaffected.
"""
import jax

jax.config.update("jax_enable_x64", True)

from . import codec, entropy, outliers, szlike, zfplike  # noqa: E402,F401
from .quantize import abs_bound_from_rel  # noqa: E402,F401


def compress(x, rel_eb=None, *, abs_eb=None, compressor="szlike", **kw):
    """Dispatch helper: ``compressor`` in {szlike, szlike-lorenzo, zfplike}."""
    if compressor == "szlike":
        return szlike.compress(x, rel_eb, abs_eb=abs_eb, **kw)
    if compressor == "szlike-lorenzo":
        cfg = kw.pop("config", szlike.SZLikeConfig(predictor="lorenzo"))
        return szlike.compress(x, rel_eb, abs_eb=abs_eb, config=cfg, **kw)
    if compressor == "zfplike":
        return zfplike.compress(x, rel_eb, abs_eb=abs_eb, **kw)
    raise ValueError(f"unknown compressor {compressor!r}")


def decompress(arc: dict):
    if arc["kind"] == "szlike":
        return szlike.decompress(arc)
    if arc["kind"] == "zfplike":
        return zfplike.decompress(arc)
    raise ValueError(f"unknown archive kind {arc['kind']!r}")


def archive_nbytes(arc: dict) -> int:
    if arc["kind"] == "szlike":
        return szlike.archive_nbytes(arc)
    return zfplike.archive_nbytes(arc)
