"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile multiples, dtype plumbing, and the interpret-mode
switch (interpret=True on CPU — the kernels TARGET TPU; this container
validates them by executing the kernel body in Python).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import dispatch
from .conv2d3x3 import conv2d3x3
from .fused_enhance import fused_enhance
from .lorenzo3d import lorenzo3d_fwd, lorenzo3d_inv


def _on_tpu() -> bool:
    # Cached process-wide probe (dispatch.force_backend overrides in tests)
    # instead of a per-call jax.default_backend() sniff.
    return dispatch.backend() == "tpu"


def _pick_tz(d: int, h: int, w: int, itemsize: int = 4,
             vmem_budget: int = 12 * 2**20) -> int:
    """Largest power-of-two slab depth whose working set (~4 slabs: two
    inputs + two outputs) fits the VMEM budget.  Depths that are not a
    multiple get padded up by the wrappers and cropped after — a ragged
    depth no longer degrades the grid to one plane per step."""
    tz = 1
    for cand in (2, 4, 8, 16, 32):
        if cand <= d and 4 * cand * h * w * itemsize <= vmem_budget:
            tz = cand
    return tz


def lorenzo_quantize(x, eb: float, *, interpret: bool | None = None):
    """Fused prequant + Lorenzo delta over a 3-D field (pads z to the tile).

    Returns (delta int32, rec) with the original depth restored.
    """
    x = jnp.asarray(x)
    if x.dtype == jnp.float64:
        x32 = x.astype(jnp.float32)  # kernel computes in fp32; rec returned fp32
    else:
        x32 = x
    interpret = (not _on_tpu()) if interpret is None else interpret
    d0, h, w = x32.shape
    tz = _pick_tz(d0, h, w)
    pad = (-d0) % tz
    if pad:
        x32 = jnp.concatenate([x32, jnp.zeros((pad, h, w), x32.dtype)], axis=0)
    delta, rec = lorenzo3d_fwd(x32, eb, tz=tz, interpret=interpret)
    return delta[:d0], rec[:d0]


def lorenzo_dequantize(delta, eb: float, *, interpret: bool | None = None):
    """Inverse: delta codes -> reconstruction (q * 2eb)."""
    delta = jnp.asarray(delta, jnp.int32)
    interpret = (not _on_tpu()) if interpret is None else interpret
    d0, h, w = delta.shape
    tz = _pick_tz(d0, h, w)
    pad = (-d0) % tz
    if pad:
        delta = jnp.concatenate([delta, jnp.zeros((pad, h, w), jnp.int32)], axis=0)
    q = lorenzo3d_inv(delta, tz=tz, interpret=interpret)
    return q[:d0].astype(jnp.float32) * (2.0 * float(eb))


def enhance(z, decomp, orig, eb: float, *, regulated: bool = True,
            strict: bool = True, interpret: bool | None = None):
    """Fused regulate+add+outlier over an N-D field; shapes all equal."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    z = jnp.asarray(z, jnp.float32)
    decomp = jnp.asarray(decomp)
    orig = jnp.asarray(orig, decomp.dtype)
    shape = decomp.shape
    w = shape[-1]
    rows = int(np.prod(shape[:-1]))
    z2, d2, o2 = (a.reshape(rows, w) for a in (z, decomp, orig))
    tr = 1
    for cand in (8, 32, 128, 256):
        if cand <= rows and cand * w * 4 * 5 <= 12 * 2**20:
            tr = cand
    pad = (-rows) % tr
    if pad:
        # Elementwise op: zero rows compute garbage that is cropped below.
        z2, d2, o2 = (jnp.concatenate(
            [a, jnp.zeros((pad, w), a.dtype)], axis=0) for a in (z2, d2, o2))
    out, mask = fused_enhance(z2, d2, o2, eb, regulated=regulated,
                              strict=strict, tr=tr, interpret=interpret)
    return out[:rows].reshape(shape), mask[:rows].reshape(shape)


def conv3x3(x, w, b, *, stride: int = 1, relu: bool = True,
            interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    x, w, b = jnp.asarray(x), jnp.asarray(w), jnp.asarray(b)
    cout = w.shape[-1]
    pad = cout % 2
    if pad:
        # Odd output-channel counts (the network head is C_out=1) lower as a
        # GEMV; pad to an even C_out so every contraction is the same batched
        # GEMM shape, then crop.  Exact: padded channels are computed and
        # sliced off, kept channels are untouched.
        w = jnp.concatenate([w, jnp.zeros(w.shape[:-1] + (pad,), w.dtype)],
                            axis=-1)
        b = jnp.concatenate([b, jnp.zeros((pad,), b.dtype)])
    out = conv2d3x3(x, w, b, stride=stride, relu=relu, interpret=interpret)
    return out[..., :cout] if pad else out
