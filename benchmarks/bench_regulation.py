"""Paper Fig 13 / §5.1: strict vs relaxed vs unregulated quality and the
outlier-storage trade-off (does doubling the bound pay for itself?)."""
from __future__ import annotations

import time


from . import common
from repro.core import metrics
from repro.data import fields as F


def run(full: bool = False):
    shape = (32, 48, 48) if full else (24, 40, 40)
    epochs = 30 if full else 20
    flds = F.make_fields("nyx", shape=shape, seed=2)
    x = flds["temperature"]
    for mode in ("strict", "relaxed", "unregulated"):
        t0 = time.time()
        arc, dec, out, _ = common.run_neurlz({"f": x}, 1e-3, mode=mode,
                                             epochs=epochs)
        r = out["f"]
        d = dec["f"]
        common.csv_row(
            f"fig13/{mode}", (time.time() - t0) * 1e6,
            f"psnr={r['psnr']:.2f};mae={r['mae']:.3e};"
            f"dssim={metrics.dssim(x, d):.5f};"
            f"bitrate={r['bitrate']:.3f};"
            f"maxerr_over_eb={r['max_err_over_eb']:.2f}")


if __name__ == "__main__":
    run()
