"""Pluggable conventional-compressor registry.

The old dispatch was an if/elif chain over hardcoded names, and
``archive_nbytes`` silently fell through to the zfplike accounting for any
archive kind it did not recognize.  This module replaces both with explicit
registration: a compressor registers its name, capability metadata and entry
points once, and every engine (serial / batched / streaming) resolves it
through the same table.  Third-party compressors become a
:func:`register` call instead of a core edit:

    from repro.compressors import registry

    registry.register(registry.CompressorEntry(
        name="mylz", kind="mylz",
        compress=my_compress,          # (x, rel_eb, *, abs_eb=None, **kw)
        decompress=my_decompress,      # (arc) -> np.ndarray
        archive_nbytes=my_nbytes,      # (arc) -> int
    ))

Capability metadata drives the batched conventional stage
(:mod:`repro.core.conv_stage`): an entry that provides
``compress_batched`` declares that compressing a stacked ``[F, ...]``
group of same-shape/same-dtype fields yields payloads **byte-identical**
to ``F`` per-field calls (the bit-stable-lowering contract — conventional
archives must match across engines).  Entries without it always run
per-field.

Archive *kinds* are registered separately from compressor names because
several compressors may share an archive format (``szlike`` and
``szlike-lorenzo`` both emit ``kind="szlike"``); decode-side dispatch
(``decompress`` / ``archive_nbytes``) goes by the archive's ``kind`` tag.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import numpy as np


@dataclasses.dataclass(frozen=True)
class CompressorEntry:
    """One registered conventional compressor.

    ``compress(x, rel_eb, *, abs_eb=None, **kw) -> (archive_dict, rec)``
    must uphold the determinism contract: the returned reconstruction is
    bit-identical to what ``decompress(archive_dict)`` produces (NeurLZ
    trains its enhancer against the encoder-side reconstruction).

    ``compress_batched(xs, rel_eb, *, abs_eb=None) -> list[(arc, rec)]``
    (optional) takes a stacked ``[F, ...]`` array of same-shape/same-dtype
    fields and must return per-field archives whose payloads are
    byte-identical to ``F`` independent ``compress`` calls — the capability
    that unlocks the fused conv-stage group dispatch.

    ``decompress_batched(arcs) -> list[np.ndarray]`` (optional, the
    symmetric decode capability) takes archives that agree on
    ``decode_key`` and must return reconstructions **bit-identical** to one
    ``decompress`` call per archive, produced by the same stacked eager-op
    sequence discipline as the encode side (no jit — FMA contraction would
    change float bits).  ``decode_key(arc)`` is the hashable an archive
    must match on to share a stacked decode dispatch (shape/dtype plus any
    layout fields like the predictor or interpolation level; the per-field
    error bound rides along as a broadcast vector, exactly as it does on
    the encode side).
    """

    name: str
    kind: str                                # archive "kind" tag it emits
    compress: Callable
    decompress: Callable
    archive_nbytes: Callable
    compress_batched: Callable | None = None
    decompress_batched: Callable | None = None
    decode_key: Callable | None = None       # (arc) -> hashable group key
    dtypes: tuple = ("float32", "float64")   # dtypes the batched path covers
    deterministic: bool = True               # encoder rec == decoder output
    description: str = ""

    @property
    def batchable(self) -> bool:
        return self.compress_batched is not None

    @property
    def decode_batchable(self) -> bool:
        return (self.decompress_batched is not None
                and self.decode_key is not None)

    def batch_supports(self, dtype) -> bool:
        return self.batchable and str(np.dtype(dtype)) in self.dtypes

    def decode_batch_supports(self, arc: dict) -> bool:
        return (self.decode_batchable
                and str(np.dtype(arc.get("dtype", "float32"))) in self.dtypes)


_COMPRESSORS: dict[str, CompressorEntry] = {}
_KINDS: dict[str, CompressorEntry] = {}


def register(entry: CompressorEntry, *, overwrite: bool = False) -> CompressorEntry:
    """Register a compressor (and its archive kind, if new).

    Entries sharing an archive ``kind`` must agree on the decode-side entry
    points — the first registration of a kind owns its ``decompress`` /
    ``archive_nbytes`` dispatch.
    """
    if entry.name in _COMPRESSORS and not overwrite:
        raise ValueError(f"compressor {entry.name!r} already registered "
                         "(pass overwrite=True to replace)")
    owner = _KINDS.get(entry.kind)
    if owner is not None and owner.name != entry.name and (
            owner.decompress is not entry.decompress
            or owner.archive_nbytes is not entry.archive_nbytes
            or owner.decompress_batched is not entry.decompress_batched
            or owner.decode_key is not entry.decode_key):
        raise ValueError(
            f"archive kind {entry.kind!r} is owned by {owner.name!r} with "
            "different decode entry points (incl. decompress_batched/"
            "decode_key); kinds must decode unambiguously")
    _COMPRESSORS[entry.name] = entry
    if owner is None or owner.name == entry.name:
        _KINDS[entry.kind] = entry
    return entry


def unregister(name: str) -> None:
    entry = _COMPRESSORS.pop(name, None)
    if entry is not None and _KINDS.get(entry.kind) is entry:
        # Hand the kind to any remaining entry that shares it.
        del _KINDS[entry.kind]
        for other in _COMPRESSORS.values():
            if other.kind == entry.kind:
                _KINDS[entry.kind] = other
                break


def get(name: str) -> CompressorEntry:
    try:
        return _COMPRESSORS[name]
    except KeyError:
        raise ValueError(
            f"unknown compressor {name!r} (registered: {sorted(_COMPRESSORS)})"
        ) from None


def for_archive(arc: dict) -> CompressorEntry:
    """Resolve the entry owning an archive dict's ``kind`` tag."""
    kind = arc.get("kind")
    try:
        return _KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown archive kind {kind!r} (registered: {sorted(_KINDS)})"
        ) from None


def names() -> list[str]:
    return sorted(_COMPRESSORS)


def entries() -> list[CompressorEntry]:
    return [_COMPRESSORS[n] for n in names()]


# ---------------------------------------------------------------------------
# Dispatch helpers (the public compressors.* API routes through these)
# ---------------------------------------------------------------------------

def compress(x, rel_eb=None, *, abs_eb=None, compressor="szlike", **kw):
    return get(compressor).compress(x, rel_eb, abs_eb=abs_eb, **kw)


def decompress(arc: dict):
    return for_archive(arc).decompress(arc)


def archive_nbytes(arc: dict) -> int:
    # No fall-through: an unknown kind is a hard error (it used to be
    # silently accounted with the zfplike layout).
    return for_archive(arc).archive_nbytes(arc)


class DecodeStats:
    """Thread-safe accounting of conventional-decode dispatches.

    Hand an instance to :func:`decompress_many` (``stats=``) and it records
    how the call actually executed: how many stacked
    ``decompress_batched`` dispatches ran (``batched``), how many archives
    decoded one at a time (``single``), the total archives decoded and the
    widest stacked dispatch seen.  The serving tier's coalescing guarantee
    — *N same-signature requests execute as one stacked dispatch* — is
    asserted against these numbers (tests and the ``bench_serving`` smoke
    guard), so the counters are part of the dispatch contract, not just
    telemetry.
    """

    __slots__ = ("_lock", "batched", "single", "archives", "max_width")

    def __init__(self):
        self._lock = threading.Lock()
        self.batched = 0        # stacked decompress_batched dispatches
        self.single = 0         # per-archive decompress calls
        self.archives = 0       # total archives decoded
        self.max_width = 0      # widest stacked dispatch

    def note(self, width: int) -> None:
        with self._lock:
            self.archives += width
            if width > 1:
                self.batched += 1
                self.max_width = max(self.max_width, width)
            else:
                self.single += 1

    @property
    def dispatches(self) -> int:
        """Total decode dispatches (stacked + per-archive)."""
        return self.batched + self.single

    def as_dict(self) -> dict:
        return {"batched": self.batched, "single": self.single,
                "dispatches": self.dispatches, "archives": self.archives,
                "max_width": self.max_width}

    def __repr__(self) -> str:
        return (f"DecodeStats(batched={self.batched}, single={self.single}, "
                f"archives={self.archives}, max_width={self.max_width})")


def decompress_many(arcs, *, batch: bool = True,
                    stats: DecodeStats | None = None) -> dict:
    """Decode a set of conventional archives, batching where possible.

    ``arcs`` maps name -> archive dict.  Archives whose entry declares
    ``decompress_batched`` and that agree on the entry's ``decode_key``
    run as one stacked eager dispatch; everything else decodes per-archive.
    Outputs are bit-identical to per-archive :func:`decompress` either way
    (the decode-side mirror of the conv stage's encode contract), so every
    caller — batched-engine decode, streaming ``iter_decompress``, the
    ``Archive`` handle's random access, the serving tier — may use this
    unconditionally.  ``stats`` (a :class:`DecodeStats`) receives one
    ``note(width)`` per dispatch actually issued.
    """
    out: dict = {}
    groups: dict[tuple, list] = {}
    for name, arc in arcs.items():
        entry = for_archive(arc)
        if batch and entry.decode_batch_supports(arc):
            k = (entry.name, entry.decode_key(arc))
        else:
            k = (entry.name, ("__single__", name))
        groups.setdefault(k, []).append((name, arc, entry))
    for members in groups.values():
        entry = members[0][2]
        if len(members) > 1:    # only decode_key-matched archives group
            recs = entry.decompress_batched([arc for _, arc, _ in members])
            for (name, _, _), rec in zip(members, recs):
                out[name] = rec
            if stats is not None:
                stats.note(len(members))
        else:
            for name, arc, e in members:
                out[name] = e.decompress(arc)
                if stats is not None:
                    stats.note(1)
    return {name: out[name] for name in arcs}


def _register_builtins() -> None:
    """Built-in compressors; imported lazily so this module stays cheap to
    import from documentation/tooling contexts."""
    from . import szlike, zfplike

    def _lorenzo_compress(x, rel_eb=None, *, abs_eb=None, **kw):
        cfg = kw.pop("config", szlike.SZLikeConfig(predictor="lorenzo"))
        return szlike.compress(x, rel_eb, abs_eb=abs_eb, config=cfg, **kw)

    def _lorenzo_batched(xs, rel_eb=None, *, abs_eb=None, **kw):
        cfg = kw.pop("config", szlike.SZLikeConfig(predictor="lorenzo"))
        return szlike.compress_batched(xs, rel_eb, abs_eb=abs_eb, config=cfg,
                                       **kw)

    register(CompressorEntry(
        name="szlike", kind="szlike",
        compress=szlike.compress, decompress=szlike.decompress,
        archive_nbytes=szlike.archive_nbytes,
        compress_batched=szlike.compress_batched,
        decompress_batched=szlike.decompress_batched,
        decode_key=szlike.decode_key,
        description="SZ3-style multilevel cubic-interpolation predictor"))
    register(CompressorEntry(
        name="szlike-lorenzo", kind="szlike",
        compress=_lorenzo_compress, decompress=szlike.decompress,
        archive_nbytes=szlike.archive_nbytes,
        compress_batched=_lorenzo_batched,
        decompress_batched=szlike.decompress_batched,
        decode_key=szlike.decode_key,
        description="cuSZ-style dual-quantization Lorenzo predictor"))
    register(CompressorEntry(
        name="zfplike", kind="zfplike",
        compress=zfplike.compress, decompress=zfplike.decompress,
        archive_nbytes=zfplike.archive_nbytes,
        compress_batched=zfplike.compress_batched,
        decompress_batched=zfplike.decompress_batched,
        decode_key=zfplike.decode_key,
        description="ZFP-style block-transform with exact correction pass"))
