"""First-class NeurLZ session API.

The paper frames NeurLZ as a *service* (§3.1): hand in snapshot fields with
user-input error bounds, get strictly regulated reconstructions back.  This
module is that surface:

    import repro

    sess = repro.NeurLZ(engine=repro.EngineConfig(engine="batched"),
                        model=repro.ModelConfig(epochs=8))
    arc = sess.compress(fields, bounds={
        "temperature": repro.ErrorBound(rel=1e-3),
        "pressure":    repro.ErrorBound(abs=2e-2, mode="relaxed"),
    })
    arc.save("snap.nlz")

    with repro.Archive.open("snap.nlz") as arc:
        t = arc.decode("temperature")        # lazy random access

Configuration is split by concern — :class:`ModelConfig` (the enhancer and
its online training), :class:`EngineConfig` (which engine runs it and how),
:class:`RegulationConfig` (the default error-regulation mode) — while the
flat :class:`repro.core.NeurLZConfig` keeps working everywhere: flat kwargs
passed to :class:`NeurLZ` are forwarded into the right sub-config, and
``NeurLZ(config=flat_cfg)`` adopts an existing one wholesale.  Internally
the engines still consume the flat dataclass; :func:`join_config` /
:func:`split_config` are the lossless bridge.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Mapping

from . import faults as faults_lib
from .core import bounds as bounds_lib
from .core import neurlz
from .core.archive import CorruptArchiveError
from .core.archive_api import Archive
from .faults import FaultConfig, FaultInjector, InjectedFault, RetryPolicy
from .core.bounds import ErrorBound
from .core.neurlz import NeurLZConfig
from .obs import telemetry as obs
from .obs.telemetry import Telemetry, TelemetryConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """The skipping-DNN enhancer and its compression-time training."""

    widths: tuple = (4, 4, 6, 6, 8)
    skip: bool = True                   # skipping vs plain DNN (ablation)
    learn_residual: bool = True         # residual vs direct learning
    weight_dtype: str = "float32"       # archive precision for DNN weights
    epochs: int = 100
    batch: int = 10
    lr: float = 1e-2
    seed: int = 0
    slice_axis: int = 0
    cross_field: Mapping[str, tuple] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Which engine executes a compression run, and how."""

    engine: str = "serial"              # serial | batched | streaming
    compressor: str = "szlike"          # conventional stage (registry name)
    conv_batch: bool = True             # snapshot-batched conventional stage
    field_batching: str = "auto"        # auto | unroll | vmap (stacked)
    lowering: str = "auto"              # eager | jit | pallas | auto — kernel
    #   lowering for the hot ops (byte-identical-or-fallback contract)
    group_size: int = 2                 # fields per batched dispatch (0=all)
    prefetch: bool = True               # overlap conv stage with training
    field_shard: bool = True            # spread field groups over devices
    max_resident_bytes: int = 0         # streaming residency budget (0=off)
    telemetry: object | None = None     # repro.obs.Telemetry handle (None =
    #   disabled; instrumentation degrades to shared no-op singletons)
    faults: object | None = None        # repro.faults.FaultConfig (None =
    #   defaults: no injection, no retries, degradation on)


@dataclasses.dataclass(frozen=True)
class RegulationConfig:
    """Default error regulation; per-field :class:`ErrorBound` specs
    override ``mode`` field by field."""

    mode: str = "strict"                # strict | relaxed | unregulated


_MODEL_FIELDS = tuple(f.name for f in dataclasses.fields(ModelConfig))
_ENGINE_FIELDS = tuple(f.name for f in dataclasses.fields(EngineConfig))
_REG_FIELDS = tuple(f.name for f in dataclasses.fields(RegulationConfig))


def join_config(model: ModelConfig, engine: EngineConfig,
                regulation: RegulationConfig) -> NeurLZConfig:
    """Flatten the sub-configs into the engines' :class:`NeurLZConfig`."""
    kw = {}
    for f in _MODEL_FIELDS:
        kw[f] = getattr(model, f)
    for f in _ENGINE_FIELDS:
        kw[f] = getattr(engine, f)
    for f in _REG_FIELDS:
        kw[f] = getattr(regulation, f)
    return NeurLZConfig(**kw)


def split_config(config: NeurLZConfig
                 ) -> tuple[ModelConfig, EngineConfig, RegulationConfig]:
    """Inverse of :func:`join_config` (lossless: the three sub-configs
    partition every ``NeurLZConfig`` field)."""
    return (
        ModelConfig(**{f: getattr(config, f) for f in _MODEL_FIELDS}),
        EngineConfig(**{f: getattr(config, f) for f in _ENGINE_FIELDS}),
        RegulationConfig(**{f: getattr(config, f) for f in _REG_FIELDS}),
    )


class NeurLZ:
    """A configured NeurLZ compression session.

    Construct from sub-configs, a flat :class:`NeurLZConfig`, flat kwargs,
    or any mix (kwargs win over the config object they land in):

        NeurLZ()                                    # paper defaults
        NeurLZ(engine=EngineConfig(engine="batched"))
        NeurLZ(config=flat_neurlz_config)           # adopt a flat config
        NeurLZ(epochs=8, mode="relaxed")            # flat kwargs, forwarded

    The session is stateless between calls (compression is online per
    snapshot); it exists to hold configuration and give ``compress`` /
    ``decompress`` an object home.
    """

    def __init__(self, model: ModelConfig | None = None,
                 engine: EngineConfig | None = None,
                 regulation: RegulationConfig | None = None, *,
                 config: NeurLZConfig | None = None, **flat_kwargs):
        # `engine` is both a sub-config parameter and a flat NeurLZConfig
        # field name; a string here is the flat field (engine="batched"),
        # matching the kwarg-forwarding contract and `replace(engine=...)`.
        if isinstance(engine, str):
            flat_kwargs.setdefault("engine", engine)
            engine = None
        if config is not None:
            m0, e0, r0 = split_config(config)
        else:
            m0, e0, r0 = ModelConfig(), EngineConfig(), RegulationConfig()
        model = model if model is not None else m0
        engine = engine if engine is not None else e0
        regulation = regulation if regulation is not None else r0
        # Flat NeurLZConfig kwargs: forwarded into the right sub-config.
        mkw, ekw, rkw = {}, {}, {}
        for k, v in flat_kwargs.items():
            if k in _MODEL_FIELDS:
                mkw[k] = v
            elif k in _ENGINE_FIELDS:
                ekw[k] = v
            elif k in _REG_FIELDS:
                rkw[k] = v
            else:
                raise TypeError(f"unknown NeurLZ config field {k!r}")
        self.model = dataclasses.replace(model, **mkw)
        self.engine = dataclasses.replace(engine, **ekw)
        self.regulation = dataclasses.replace(regulation, **rkw)

    @property
    def config(self) -> NeurLZConfig:
        """The flat config the engines consume."""
        return join_config(self.model, self.engine, self.regulation)

    def replace(self, **flat_kwargs) -> "NeurLZ":
        """A new session with flat config fields replaced."""
        return NeurLZ(config=self.config, **flat_kwargs)

    # -- compression --------------------------------------------------------

    def compress(self, fields: Mapping, bounds=None, *,
                 rel_eb: float | None = None, abs_eb: float | None = None,
                 collect_stats: bool = True) -> Archive:
        """Compress one snapshot's fields; returns an :class:`Archive`.

        ``bounds`` is the per-field error-bound surface: a single
        :class:`ErrorBound` (or bare relative bound) for every field, or a
        mapping ``name -> spec`` — fields missing from the mapping fall
        back to ``rel_eb``/``abs_eb``.  Each field honors *its own* bound
        and regulation mode.  With only ``rel_eb``/``abs_eb`` the call is
        exactly the classic single-bound run (bit-identical archives).
        """
        arc = neurlz.compress_impl(fields, rel_eb, abs_eb=abs_eb,
                                   config=self.config,
                                   collect_stats=collect_stats,
                                   bounds=bounds)
        handle = Archive.from_dict(arc)
        if self.engine.telemetry is not None:
            handle.telemetry = self.engine.telemetry
        if self.engine.faults is not None:
            handle.faults = self.engine.faults
        return handle

    def compress_to(self, source, sink, bounds=None, *,
                    rel_eb: float | None = None,
                    abs_eb: float | None = None,
                    collect_stats: bool = True,
                    resume: bool = False) -> Archive:
        """Stream-compress ``source`` into ``sink`` (out-of-core path).

        ``source`` is anything :func:`repro.streaming.source.as_source`
        accepts; ``sink`` a path or binary file object.  Runs the bounded-
        memory streaming pipeline regardless of ``engine.engine`` and
        returns a **lazy** :class:`Archive` over the written container,
        with the pipeline report attached as ``archive.report``.

        ``resume=True``: if ``sink`` holds a partial container from an
        interrupted run of the *same* configuration, salvage its sealed
        entries and compress only the remaining fields — the finished
        container is byte-identical per entry to an uninterrupted run.  A
        config mismatch is a hard error (silently resuming under different
        settings would break the determinism contract).
        """
        from .streaming import pipeline
        if isinstance(sink, os.PathLike):
            sink = os.fspath(sink)
        cfg = self.config
        if cfg.engine != "streaming":
            cfg = dataclasses.replace(cfg, engine="streaming")
        report = pipeline.compress(source, sink, rel_eb, abs_eb=abs_eb,
                                   config=cfg, collect_stats=collect_stats,
                                   bounds=bounds, resume=resume)
        arc = Archive.open(sink)
        arc.report = report
        if self.engine.telemetry is not None:
            arc.telemetry = self.engine.telemetry
        if self.engine.faults is not None:
            arc.faults = self.engine.faults
        return arc

    # -- decode -------------------------------------------------------------

    def decompress(self, archive, *, reassemble: bool = False) -> dict:
        """Full decode of an :class:`Archive` (or legacy archive dict) with
        this session's engine (``batched`` fuses inference dispatches;
        anything else decodes serially)."""
        arc = Archive.from_dict(archive)
        if (self.engine.telemetry is not None
                and arc.telemetry is obs.NULL):
            arc.telemetry = self.engine.telemetry
        if (self.engine.faults is not None
                and arc.faults is faults_lib.DEFAULT):
            arc.faults = self.engine.faults
        engine = "batched" if self.engine.engine == "batched" else "serial"
        return arc.decode_all(engine=engine, reassemble=reassemble)

    def __repr__(self) -> str:
        return (f"NeurLZ(engine={self.engine.engine!r}, "
                f"compressor={self.engine.compressor!r}, "
                f"mode={self.regulation.mode!r}, "
                f"epochs={self.model.epochs})")


def open(path) -> Archive:  # noqa: A001 - deliberate, repro.open(path)
    """Module-level convenience: :meth:`Archive.open`."""
    return Archive.open(path)


def __getattr__(name: str):
    # The serving tier re-exports lazily: `repro.ArchiveServer` /
    # `repro.transcode` should not make `import repro.api` (and therefore
    # every NeurLZ() construction) pay the serve/streaming import chain.
    if name in ("ArchiveServer", "transcode"):
        from . import serve
        value = getattr(serve, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")


__all__ = ["NeurLZ", "Archive", "ArchiveServer", "ErrorBound", "ModelConfig",
           "EngineConfig", "RegulationConfig", "NeurLZConfig", "Telemetry",
           "TelemetryConfig", "FaultConfig", "FaultInjector", "InjectedFault",
           "RetryPolicy", "CorruptArchiveError", "join_config", "split_config",
           "open", "transcode"]

# Re-exported for API-surface completeness (resolve_bounds powers the
# ``bounds=`` argument coercion rules documented above).
resolve_bounds = bounds_lib.resolve_bounds
