"""Batched serving driver: prefill a prompt batch, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \\
        --batch 4 --prompt-len 64 --gen 32

Serving architecture: fixed-capacity KV cache allocated once per batch
(``max_len = prompt + gen``), prefill fills it via teacher-forced forward,
then the decode step (one token/seq) runs jit-compiled with donated cache.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..data.tokens import TokenStream
from ..models import model as M


def prefill_into_cache(model, params, tokens, max_len):
    """Teacher-forced prefill: run decode_step over the prompt positions.

    (The training forward doesn't capture per-layer caches through the scan
    segments; sequential prefill is exact and shares the decode kernel —
    production would use a chunked prefill kernel.)
    """
    b, plen = tokens.shape
    cache = model.init_cache(b, max_len)
    step = jax.jit(M.make_decode_step(model), donate_argnums=(1,))
    logits = None
    for pos in range(plen):
        logits, cache = step(params, cache, tokens[:, pos:pos + 1],
                             jnp.asarray(pos, jnp.int32))
    return logits, cache, plen


def serve(args) -> dict:
    cfg = configs.get_reduced(args.arch)
    if cfg.family == "audio":
        raise SystemExit("encoder-only arch has no decode loop")
    model = M.build_model(cfg, model_axis=1)
    params = M.init_params(model, seed=args.seed)

    stream = TokenStream(cfg.vocab_size, args.batch, args.prompt_len,
                         seed=args.seed)
    prompts = jnp.asarray(stream.next_batch())
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    logits, cache, pos = prefill_into_cache(model, params, prompts, max_len)
    prefill_s = time.time() - t0

    step = jax.jit(M.make_decode_step(model), donate_argnums=(1,))
    toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [np.asarray(toks)]
    t1 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, toks,
                             jnp.asarray(pos + i, jnp.int32))
        toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(np.asarray(toks))
    jax.block_until_ready(logits)
    decode_s = time.time() - t1

    gen = np.concatenate(out, axis=1)
    report = {
        "arch": args.arch, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": int(gen.shape[1]),
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_tok_per_s": round(args.batch * (args.gen - 1) / max(decode_s, 1e-9), 1),
        "sample_tokens": gen[0, :10].tolist(),
    }
    print(json.dumps(report, indent=1))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    serve(args)


if __name__ == "__main__":
    main()
