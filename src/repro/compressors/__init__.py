"""Conventional error-bounded lossy compressors (the substrate NeurLZ enhances).

FP64 scientific data (Miranda) needs double-precision reconstruction, so the
compression stack runs with x64 enabled.  Model code always passes explicit
dtypes and is unaffected.

Dispatch goes through the pluggable registry (:mod:`repro.compressors.registry`):
``compress`` resolves a registered compressor by name, ``decompress`` /
``archive_nbytes`` resolve the archive's ``kind`` tag, and unknown names or
kinds are hard errors.  Register additional compressors with
``registry.register(registry.CompressorEntry(...))``.
"""
import jax

jax.config.update("jax_enable_x64", True)

from . import codec, entropy, outliers, registry, szlike, zfplike  # noqa: E402,F401
from .quantize import abs_bound_from_rel  # noqa: E402,F401

registry._register_builtins()


def compress(x, rel_eb=None, *, abs_eb=None, compressor="szlike", **kw):
    """Dispatch helper over the registry (built-ins: szlike, szlike-lorenzo,
    zfplike)."""
    return registry.compress(x, rel_eb, abs_eb=abs_eb, compressor=compressor,
                             **kw)


def decompress(arc: dict):
    return registry.decompress(arc)


def decompress_many(arcs, *, batch: bool = True) -> dict:
    """Decode ``{name: archive}``, fusing same-``decode_key`` archives
    through the registry's stacked ``decompress_batched`` capability
    (bit-identical to per-archive :func:`decompress`)."""
    return registry.decompress_many(arcs, batch=batch)


def archive_nbytes(arc: dict) -> int:
    return registry.archive_nbytes(arc)
