"""Paper Figs 7/12/16: per-epoch evolution of PSNR, outlier rate (OLR) and
max-abs-error for regulated vs unregulated training."""
from __future__ import annotations

import time

import jax
import numpy as np

from . import common
from repro import compressors as C
from repro.core import metrics, online_trainer, regulation, skipping_dnn
from repro.data import fields as F


def run(full: bool = False):
    shape = (32, 48, 48) if full else (24, 40, 40)
    n_epochs = 24 if full else 12
    flds = F.make_fields("nyx", shape=shape, seed=2)
    for name in ("temperature", "velocity_y"):
        x = flds[name]
        arc, rec = C.compress(x, 1e-3, compressor="szlike")
        eb = arc["abs_eb"]
        for regulated in (True, False):
            net_cfg = skipping_dnn.SkippingDNNConfig(c_in=1, regulated=regulated)
            tcfg = online_trainer.TrainConfig(epochs=n_epochs, batch=10)
            inputs, targets, stats = online_trainer.make_dataset(rec, x, eb)
            params = skipping_dnn.init_params(jax.random.PRNGKey(0), net_cfg)
            opt = None
            t0 = time.time()
            for epoch in range(n_epochs):
                params, opt, hist = online_trainer.train(
                    params, inputs, targets, tcfg, net_cfg, opt_state=opt,
                    start_epoch=epoch, epochs=1)
                resid = online_trainer.predict_residual(params, inputs, net_cfg)
                resid = np.moveaxis(resid, 0, 0)
                enh = regulation.enhance(rec, resid, eb)
                err = np.abs(enh.astype(np.float64) - x.astype(np.float64))
                psnr = metrics.psnr(x, enh)
                olr = float((err > eb).mean() * 100)
                tag = "regulated" if regulated else "unregulated"
                common.csv_row(
                    f"fig12/{name}/{tag}/epoch{epoch + 1}",
                    (time.time() - t0) * 1e6,
                    f"psnr={psnr:.2f};olr_pct={olr:.3f};"
                    f"maxerr_over_eb={err.max() / eb:.3f}")


if __name__ == "__main__":
    run()
