"""Composable model stacks covering all 10 assigned architectures.

Depth is organized as *segments*: each segment is a ``lax.scan`` over a
stack of identically-structured layers (params stacked on a leading dim), so
HLO size and compile time are O(#segments), not O(depth) — essential when
lowering 81-layer models against a 512-device mesh.  Heterogeneous archs
(gemma3 5:1 local:global, zamba2 mamba+shared-attn, deepseek dense-then-MoE,
xlstm mLSTM+sLSTM) become 1–3 segments of repeating *units*.

Layer steps are wrapped in ``jax.checkpoint`` (configurable policy) so the
backward pass rematerializes activations — the §Perf pass tunes the policy.

The cross-entropy loss is computed in sequence chunks (never materializing
the full [B, S, V] logits — with 262k vocabs that tensor would dominate HBM).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import attention, mlp, moe, ssm, xlstm
from .layers import dense_init, embed_init, rmsnorm

REMAT_POLICIES = {
    "nothing": None,  # full remat
    "dots": "dots_with_no_batch_dims_saveable",
}


def _remat(fn, policy: str = "nothing"):
    name = REMAT_POLICIES.get(policy)
    if name is None:
        return jax.checkpoint(fn)
    return jax.checkpoint(fn, policy=getattr(jax.checkpoint_policies, name))


def pad_vocab(v: int, mult: int = 16) -> int:
    return int(np.ceil(v / mult) * mult)


# ---------------------------------------------------------------------------
# block initializers (one layer each); stacked via vmap over a key axis
# ---------------------------------------------------------------------------

def _attn_mlp_init(key, cfg, dtype, d_ff=None):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attention.init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": mlp.init(k2, cfg.d_model, d_ff or cfg.d_ff, dtype),
    }


def _attn_moe_init(key, cfg, dtype, model_axis):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": attention.init(k1, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "moe": moe.init(k2, cfg, dtype, model_axis),
    }


def _mamba_init(key, cfg, dtype):
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            "mamba": ssm.init(key, cfg, dtype)}


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# block forward steps
# ---------------------------------------------------------------------------

def _sp(cfg, x):
    if cfg.sp_residual:
        from ..distributed.sharding import constrain
        return constrain(x, ("batch", "model", None))
    return x


def _attn_mlp_fwd(p, cfg, x, positions, window, theta):
    x = _sp(cfg, x)
    h, _ = attention.forward(p["attn"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
                             positions, window=window, theta=theta,
                             skip_uncausal=cfg.attn_skip_uncausal)
    x = x + h
    x = x + mlp.forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return x


def _attn_moe_fwd(p, cfg, x, positions, model_axis):
    x = _sp(cfg, x)
    h, _ = attention.forward(p["attn"], cfg, rmsnorm(x, p["ln1"], cfg.norm_eps),
                             positions, skip_uncausal=cfg.attn_skip_uncausal)
    x = x + h
    y, aux = moe.forward(p["moe"], cfg, rmsnorm(x, p["ln2"], cfg.norm_eps),
                         model_axis=model_axis)
    return x + y, aux


def _mamba_fwd(p, cfg, x):
    x = _sp(cfg, x)
    return x + ssm.forward(p["mamba"], cfg, rmsnorm(x, p["ln"], cfg.norm_eps))


# ---------------------------------------------------------------------------
# model families
# ---------------------------------------------------------------------------

class Model:
    """Thin functional namespace: init / forward / decode per family."""

    def __init__(self, cfg, model_axis: int = 16):
        self.cfg = cfg
        self.model_axis = model_axis

    # ---- init -------------------------------------------------------------
    def init(self, key):
        cfg = self.cfg
        dtype = cfg.params_dtype
        vpad = pad_vocab(cfg.vocab_size)
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": embed_init(keys[0], vpad, cfg.d_model, dtype),
            "ln_f": jnp.zeros((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["w_unembed_in"] = dense_init(keys[1], cfg.d_model, vpad, dtype)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            if cfg.pattern_local:  # gemma3 local:global units
                unit = cfg.pattern_local + cfg.pattern_global
                n_units = cfg.n_layers // unit
                rem = cfg.n_layers - n_units * unit
                params["units"] = _stack_init(
                    lambda k: _stack_init(
                        lambda kk: _attn_mlp_init(kk, cfg, dtype), k, unit),
                    keys[2], n_units)
                if rem:
                    params["rem"] = _stack_init(
                        lambda k: _attn_mlp_init(k, cfg, dtype), keys[3], rem)
            else:
                params["layers"] = _stack_init(
                    lambda k: _attn_mlp_init(k, cfg, dtype), keys[2], cfg.n_layers)
            if fam == "vlm":
                k5, k6 = jax.random.split(keys[4])
                params["proj"] = {  # 2-layer multimodal projector (llava)
                    "w1_in": dense_init(k5, cfg.d_model, cfg.d_model, dtype),
                    "w2_in": dense_init(k6, cfg.d_model, cfg.d_model, dtype),
                }
        elif fam == "moe":
            nd = cfg.first_dense_layers
            if nd:
                params["dense_layers"] = _stack_init(
                    lambda k: _attn_mlp_init(k, cfg, dtype, d_ff=cfg.d_ff_dense),
                    keys[2], nd)
            params["layers"] = _stack_init(
                lambda k: _attn_moe_init(k, cfg, dtype, self.model_axis),
                keys[3], cfg.n_layers - nd)
        elif fam == "hybrid":
            unit = cfg.hybrid_attn_every
            n_units = cfg.n_layers // unit
            rem = cfg.n_layers - n_units * unit
            params["mamba_units"] = _stack_init(
                lambda k: _stack_init(lambda kk: _mamba_init(kk, cfg, dtype),
                                      k, unit - 1), keys[2], n_units)
            params["shared_attn"] = _attn_mlp_init(keys[3], cfg, dtype)  # ONE copy
            if rem:
                params["mamba_rem"] = _stack_init(
                    lambda k: _mamba_init(k, cfg, dtype), keys[4], rem)
        elif fam == "ssm":  # xlstm
            unit = cfg.xlstm_slstm_every
            n_units = cfg.n_layers // unit
            params["units"] = _stack_init(
                lambda k: {
                    "mlstm": _stack_init(
                        lambda kk: {"ln": jnp.zeros((cfg.d_model,), dtype),
                                    "cell": xlstm.m_init(kk, cfg, dtype)},
                        k, unit - 1),
                    "slstm": {"ln": jnp.zeros((cfg.d_model,), dtype),
                              "cell": xlstm.s_init(jax.random.fold_in(k, 7),
                                                   cfg, dtype)},
                }, keys[2], n_units)
        elif fam == "audio":
            params["in_proj_in"] = dense_init(keys[2], cfg.d_model, cfg.d_model, dtype)
            params["mask_embed"] = jnp.zeros((cfg.d_model,), dtype)
            params["layers"] = _stack_init(
                lambda k: _attn_mlp_init(k, cfg, dtype), keys[3], cfg.n_layers)
        else:
            raise ValueError(fam)
        return params

    # ---- embedding / head ---------------------------------------------------
    ONE_HOT_EMBED_MIN_VOCAB = 8192  # big vocabs: vocab-parallel one-hot matmul

    def _embed(self, params, tokens):
        cfg = self.cfg
        vpad = params["embed"].shape[0]
        if vpad >= self.ONE_HOT_EMBED_MIN_VOCAB:
            # Vocab-parallel embedding: the one-hot contraction partitions
            # cleanly under SPMD (each shard matmuls its vocab slice, then a
            # psum), unlike a gather into a vocab-sharded table, which the
            # partitioner handles by involuntary full replication.
            oh = jax.nn.one_hot(tokens, vpad, dtype=params["embed"].dtype)
            x = oh @ params["embed"]
        else:
            x = jnp.take(params["embed"], tokens, axis=0)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        from ..distributed.sharding import constrain
        return constrain(x, ("batch", None, None))

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return x @ params["embed"].T
        return x @ params["w_unembed_in"]

    # ---- forward (train/prefill) -------------------------------------------
    def forward(self, params, batch, *, remat_policy: str = "nothing"):
        cfg = self.cfg
        fam = cfg.family
        self._last_aux = None
        if fam == "audio":
            x = batch["features"].astype(cfg.params_dtype) @ params["in_proj_in"]
            mask = batch["mask"]
            x = jnp.where(mask[..., None], params["mask_embed"][None, None], x)
            b, s = x.shape[:2]
        elif fam == "vlm":
            tok = self._embed(params, batch["tokens"])
            img = batch["image_embeds"].astype(cfg.params_dtype)
            img = jax.nn.gelu(img @ params["proj"]["w1_in"]) @ params["proj"]["w2_in"]
            x = jnp.concatenate([img, tok], axis=1)
            b, s = x.shape[:2]
        else:
            x = self._embed(params, batch["tokens"])
            b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = self._run_stack(params, x, positions, remat_policy)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return x  # hidden states; logits via chunked loss or self._logits

    def _run_stack(self, params, x, positions, remat_policy):
        cfg = self.cfg
        fam = cfg.family
        ma = self.model_axis

        if fam in ("dense", "vlm", "audio"):
            if cfg.pattern_local:
                def unit_step(x, unit_p):
                    for i in range(cfg.pattern_local):
                        pl = jax.tree.map(lambda a: a[i], unit_p)
                        x = _attn_mlp_fwd(pl, cfg, x, positions,
                                          cfg.window_size, cfg.rope_theta)
                    for i in range(cfg.pattern_local,
                                   cfg.pattern_local + cfg.pattern_global):
                        pg = jax.tree.map(lambda a: a[i], unit_p)
                        x = _attn_mlp_fwd(pg, cfg, x, positions, None,
                                          cfg.rope_theta * 100.0)
                    return x, None
                x, _ = jax.lax.scan(_remat(unit_step, remat_policy), x,
                                    params["units"])
                if "rem" in params:
                    def rem_step(x, p):
                        return _attn_mlp_fwd(p, cfg, x, positions,
                                             cfg.window_size, cfg.rope_theta), None
                    x, _ = jax.lax.scan(_remat(rem_step, remat_policy), x,
                                        params["rem"])
            else:
                def step(x, p):
                    return _attn_mlp_fwd(p, cfg, x, positions, cfg.window_size,
                                         cfg.rope_theta), None
                x, _ = jax.lax.scan(_remat(step, remat_policy), x, params["layers"])
            return x

        if fam == "moe":
            if "dense_layers" in params:
                def dstep(x, p):
                    return _attn_mlp_fwd(p, cfg, x, positions, None,
                                         cfg.rope_theta), None
                x, _ = jax.lax.scan(_remat(dstep, remat_policy), x,
                                    params["dense_layers"])
            def mstep(x, p):
                y, aux = _attn_moe_fwd(p, cfg, x, positions, ma)
                return y, aux
            x, auxs = jax.lax.scan(_remat(mstep, remat_policy), x, params["layers"])
            self._last_aux = jnp.mean(auxs)
            return x

        if fam == "hybrid":
            shared = params["shared_attn"]
            def unit_step(x, unit_p):
                for i in range(cfg.hybrid_attn_every - 1):
                    pm = jax.tree.map(lambda a: a[i], unit_p)
                    x = _mamba_fwd(pm, cfg, x)
                x = _attn_mlp_fwd(shared, cfg, x, positions, None, cfg.rope_theta)
                return x, None
            x, _ = jax.lax.scan(_remat(unit_step, remat_policy), x,
                                params["mamba_units"])
            if "mamba_rem" in params:
                def rstep(x, p):
                    return _mamba_fwd(p, cfg, x), None
                x, _ = jax.lax.scan(_remat(rstep, remat_policy), x,
                                    params["mamba_rem"])
            return x

        if fam == "ssm":
            def unit_step(x, unit_p):
                for i in range(cfg.xlstm_slstm_every - 1):
                    pm = jax.tree.map(lambda a: a[i], unit_p["mlstm"])
                    x = x + xlstm.m_forward(pm["cell"], cfg,
                                            rmsnorm(x, pm["ln"], cfg.norm_eps))
                ps = unit_p["slstm"]
                x = x + xlstm.s_forward(ps["cell"], cfg,
                                        rmsnorm(x, ps["ln"], cfg.norm_eps))
                return x, None
            x, _ = jax.lax.scan(_remat(unit_step, remat_policy), x, params["units"])
            return x

        raise ValueError(fam)

    # ---- chunked loss -------------------------------------------------------
    def loss(self, params, batch, *, remat_policy: str = "nothing",
             seq_chunk: int = 512):
        cfg = self.cfg
        hidden = self.forward(params, batch, remat_policy=remat_policy)
        if cfg.family == "audio":
            targets = batch["targets"]
            weights = batch["mask"].astype(jnp.float32)  # masked-prediction
            hidden_t = hidden
        elif cfg.family == "vlm":
            s_img = batch["image_embeds"].shape[1]
            hidden_t = hidden[:, s_img:][:, :-1]
            targets = batch["tokens"][:, 1:]
            weights = jnp.ones(targets.shape, jnp.float32)
        else:
            hidden_t = hidden[:, :-1]
            targets = batch["tokens"][:, 1:]
            weights = jnp.ones(targets.shape, jnp.float32)

        s = hidden_t.shape[1]
        seq_chunk = min(seq_chunk, s)
        n_chunks = s // seq_chunk
        s_used = n_chunks * seq_chunk

        @jax.checkpoint  # bwd recomputes chunk logits: never stacks them
        def chunk_ce_body(h, t, w):
            logits = self._logits(params, h).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
            return jnp.sum((lse - gold) * w)

        def chunk_ce(carry, idx):
            h = jax.lax.dynamic_slice_in_dim(hidden_t, idx * seq_chunk,
                                             seq_chunk, axis=1)
            t = jax.lax.dynamic_slice_in_dim(targets, idx * seq_chunk,
                                             seq_chunk, axis=1)
            w = jax.lax.dynamic_slice_in_dim(weights, idx * seq_chunk,
                                             seq_chunk, axis=1)
            return carry + chunk_ce_body(h, t, w), jnp.sum(w)

        tot, ws = jax.lax.scan(chunk_ce, jnp.zeros((), jnp.float32),
                               jnp.arange(n_chunks))
        denom = jnp.maximum(jnp.sum(ws), 1.0)
        loss = tot / denom
        if s_used < s:  # tail (rare; shapes here always divide)
            pass
        aux = getattr(self, "_last_aux", None)
        if aux is not None:
            loss = loss + 0.01 * aux
        return loss

    # ---- decode -------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        dtype = cfg.params_dtype
        fam = cfg.family

        if fam in ("dense", "vlm"):
            if cfg.pattern_local:
                unit = cfg.pattern_local + cfg.pattern_global
                n_units = cfg.n_layers // unit
                rem = cfg.n_layers - n_units * unit
                # Sliding-window layers only cache the window (the gemma3
                # memory win); global layers cache the full context.
                local_len = min(max_len, (cfg.window_size or max_len))
                def stack(n, length):
                    return jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[attention.init_cache(cfg, batch, length, dtype)
                          for _ in range(n)])
                cache = {
                    "units_local": jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[stack(cfg.pattern_local, local_len)
                          for _ in range(n_units)]),
                    "units_global": jax.tree.map(
                        lambda *xs: jnp.stack(xs),
                        *[stack(cfg.pattern_global, max_len)
                          for _ in range(n_units)]),
                }
                if rem:
                    cache["rem"] = stack(rem, local_len)
                return cache
            return {"layers": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[attention.init_cache(cfg, batch, max_len, dtype)
                  for _ in range(cfg.n_layers)])}
        if fam == "moe":
            nd = cfg.first_dense_layers
            cache = {}
            if nd:
                cache["dense_layers"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[attention.init_cache(cfg, batch, max_len, dtype)
                      for _ in range(nd)])
            cache["layers"] = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[attention.init_cache(cfg, batch, max_len, dtype)
                  for _ in range(cfg.n_layers - nd)])
            return cache
        if fam == "hybrid":
            unit = cfg.hybrid_attn_every
            n_units = cfg.n_layers // unit
            rem = cfg.n_layers - n_units * unit
            cache = {
                "mamba_units": jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[jax.tree.map(lambda *ys: jnp.stack(ys),
                                   *[ssm.init_cache(cfg, batch, dtype)
                                     for _ in range(unit - 1)])
                      for _ in range(n_units)]),
                "attn": jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[attention.init_cache(cfg, batch, max_len, dtype)
                      for _ in range(n_units)]),
            }
            if rem:
                cache["mamba_rem"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[ssm.init_cache(cfg, batch, dtype) for _ in range(rem)])
            return cache
        if fam == "ssm":
            unit = cfg.xlstm_slstm_every
            n_units = cfg.n_layers // unit
            return {"units": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[{"mlstm": jax.tree.map(lambda *ys: jnp.stack(ys),
                                         *[xlstm.m_init_cache(cfg, batch)
                                           for _ in range(unit - 1)]),
                   "slstm": xlstm.s_init_cache(cfg, batch)}
                  for _ in range(n_units)])}
        raise ValueError(fam)

    def decode_step(self, params, cache, tokens, pos):
        """One token for every sequence.  tokens: [B,1]; pos: scalar int32."""
        cfg = self.cfg
        fam = cfg.family
        x = self._embed(params, tokens)

        if fam in ("dense", "vlm"):
            if cfg.pattern_local:
                def one(pl, cl, x, win, theta):
                    h = rmsnorm(x, pl["ln1"], cfg.norm_eps)
                    # Windowed layers cache only the window -> ring buffer.
                    o, nc = attention.decode_step(pl["attn"], cfg, h, cl, pos,
                                                  window=win, theta=theta,
                                                  ring=win is not None)
                    x = x + o
                    x = x + mlp.forward(pl["mlp"],
                                        rmsnorm(x, pl["ln2"], cfg.norm_eps),
                                        cfg.act)
                    return x, nc
                def unit_step(x, pc):
                    unit_p, unit_cl, unit_cg = pc
                    new_l, new_g = [], []
                    for i in range(cfg.pattern_local):
                        pl = jax.tree.map(lambda a: a[i], unit_p)
                        cl = jax.tree.map(lambda a: a[i], unit_cl)
                        x, nc = one(pl, cl, x, cfg.window_size, cfg.rope_theta)
                        new_l.append(nc)
                    for i in range(cfg.pattern_global):
                        pg = jax.tree.map(lambda a: a[cfg.pattern_local + i], unit_p)
                        cg = jax.tree.map(lambda a: a[i], unit_cg)
                        x, nc = one(pg, cg, x, None, cfg.rope_theta * 100.0)
                        new_g.append(nc)
                    return x, (jax.tree.map(lambda *ys: jnp.stack(ys), *new_l),
                               jax.tree.map(lambda *ys: jnp.stack(ys), *new_g))
                x, (new_cl, new_cg) = jax.lax.scan(
                    unit_step, x, (params["units"], cache["units_local"],
                                   cache["units_global"]))
                new_cache = {"units_local": new_cl, "units_global": new_cg}
                if "rem" in params:
                    def rem_step(x, pc):
                        p, c = pc
                        h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                        o, nc = attention.decode_step(p["attn"], cfg, h, c, pos,
                                                      window=cfg.window_size,
                                                      ring=True)
                        x = x + o
                        x = x + mlp.forward(p["mlp"],
                                            rmsnorm(x, p["ln2"], cfg.norm_eps),
                                            cfg.act)
                        return x, nc
                    x, new_rem = jax.lax.scan(rem_step, x,
                                              (params["rem"], cache["rem"]))
                    new_cache["rem"] = new_rem
            else:
                def step(x, pc):
                    p, c = pc
                    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                    o, nc = attention.decode_step(p["attn"], cfg, h, c, pos,
                                                  window=cfg.window_size)
                    x = x + o
                    x = x + mlp.forward(p["mlp"],
                                        rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
                    return x, nc
                x, new_layers = jax.lax.scan(step, x,
                                             (params["layers"], cache["layers"]))
                new_cache = {"layers": new_layers}
        elif fam == "moe":
            new_cache = {}
            if "dense_layers" in params:
                def dstep(x, pc):
                    p, c = pc
                    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                    o, nc = attention.decode_step(p["attn"], cfg, h, c, pos)
                    x = x + o
                    x = x + mlp.forward(p["mlp"],
                                        rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
                    return x, nc
                x, ncd = jax.lax.scan(dstep, x, (params["dense_layers"],
                                                 cache["dense_layers"]))
                new_cache["dense_layers"] = ncd
            def mstep(x, pc):
                p, c = pc
                h = rmsnorm(x, p["ln1"], cfg.norm_eps)
                o, nc = attention.decode_step(p["attn"], cfg, h, c, pos)
                x = x + o
                y, _ = moe.forward(p["moe"], cfg,
                                   rmsnorm(x, p["ln2"], cfg.norm_eps),
                                   model_axis=self.model_axis)
                return x + y, nc
            x, ncm = jax.lax.scan(mstep, x, (params["layers"], cache["layers"]))
            new_cache["layers"] = ncm
        elif fam == "hybrid":
            shared = params["shared_attn"]
            def unit_step(x, pc):
                unit_p, unit_mc, attn_c = pc
                new_mc = []
                for i in range(cfg.hybrid_attn_every - 1):
                    pm = jax.tree.map(lambda a: a[i], unit_p)
                    cm = jax.tree.map(lambda a: a[i], unit_mc)
                    h = rmsnorm(x, pm["ln"], cfg.norm_eps)
                    o, nc = ssm.decode_step(pm["mamba"], cfg, h, cm)
                    x = x + o
                    new_mc.append(nc)
                h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
                o, nac = attention.decode_step(shared["attn"], cfg, h, attn_c, pos)
                x = x + o
                x = x + mlp.forward(shared["mlp"],
                                    rmsnorm(x, shared["ln2"], cfg.norm_eps), cfg.act)
                return x, (jax.tree.map(lambda *ys: jnp.stack(ys), *new_mc), nac)
            x, (new_mu, new_attn) = jax.lax.scan(
                unit_step, x, (params["mamba_units"], cache["mamba_units"],
                               cache["attn"]))
            new_cache = {"mamba_units": new_mu, "attn": new_attn}
            if "mamba_rem" in params:
                def rstep(x, pc):
                    p, c = pc
                    h = rmsnorm(x, p["ln"], cfg.norm_eps)
                    o, nc = ssm.decode_step(p["mamba"], cfg, h, c)
                    return x + o, nc
                x, ncr = jax.lax.scan(rstep, x, (params["mamba_rem"],
                                                 cache["mamba_rem"]))
                new_cache["mamba_rem"] = ncr
        elif fam == "ssm":
            def unit_step(x, pc):
                unit_p, unit_c = pc
                new_m = []
                for i in range(cfg.xlstm_slstm_every - 1):
                    pm = jax.tree.map(lambda a: a[i], unit_p["mlstm"])
                    cm = jax.tree.map(lambda a: a[i], unit_c["mlstm"])
                    h = rmsnorm(x, pm["ln"], cfg.norm_eps)
                    o, nc = xlstm.m_decode_step(pm["cell"], cfg, h, cm)
                    x = x + o
                    new_m.append(nc)
                ps, cs = unit_p["slstm"], unit_c["slstm"]
                h = rmsnorm(x, ps["ln"], cfg.norm_eps)
                o, ncs = xlstm.s_decode_step(ps["cell"], cfg, h, cs)
                x = x + o
                return x, {"mlstm": jax.tree.map(lambda *ys: jnp.stack(ys), *new_m),
                           "slstm": ncs}
            x, new_units = jax.lax.scan(unit_step, x,
                                        (params["units"], cache["units"]))
            new_cache = {"units": new_units}
        else:
            raise ValueError(fam)

        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        logits = self._logits(params, x).astype(jnp.float32)
        return logits, new_cache
