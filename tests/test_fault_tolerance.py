"""Fault-injection suite: deterministic injector/retry units, the archive
writer's sticky-error semantics, and graceful per-field degradation across
all three engines.

The degradation contract is the strong one: the same injected enhancer
failure must yield **byte-identical** conv-only entries from the serial,
batched and streaming engines (the cross-engine bit-identity contract
extends to the failure path), and a degraded field still honors its exact
error bound — the conventional stage alone guarantees it.
"""
import io
import os

import numpy as np
import pytest

import repro
from repro import core, obs, streaming
from repro.core import archive as A
from repro.faults import (DEFAULT, FaultConfig, FaultInjector, InjectedFault,
                          RetryPolicy, degrade_reason, is_degradable, of,
                          retry_with_backoff)
from repro.streaming import pipeline as stream_pipeline
from repro.streaming.writer import AsyncArchiveWriter


def _snapshot(n_fields: int = 3) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(11)
    return {f"f{i}": np.cumsum(rng.standard_normal((3, 8, 8)),
                               axis=0).astype(np.float32)
            for i in range(n_fields)}


# -- injector ----------------------------------------------------------------

def test_injector_fires_at_exact_invocation():
    inj = FaultInjector({"writer.add_entry": 1})
    inj.check("writer.add_entry")               # invocation 0: passes
    with pytest.raises(InjectedFault) as ei:
        inj.check("writer.add_entry")           # invocation 1: fires
    assert ei.value.site == "writer.add_entry"
    assert ei.value.invocation == 1
    inj.check("writer.add_entry")               # invocation 2: healed
    assert inj.count("writer.add_entry") == 3
    assert inj.hits == [("writer.add_entry", 1)]


def test_injector_prefix_matching_and_isolation():
    inj = FaultInjector({"train.*": 0})
    with pytest.raises(InjectedFault):
        inj.check("train.temperature")
    with pytest.raises(InjectedFault):
        inj.check("train.pressure")             # per-site invocation counts
    inj.check("decode.entry")                   # unmatched site: no-op


def test_injector_iterable_plan():
    inj = FaultInjector({"s": [0, 2]})
    for i in range(4):
        if i in (0, 2):
            with pytest.raises(InjectedFault):
                inj.check("s")
        else:
            inj.check("s")


# -- retry -------------------------------------------------------------------

def test_retry_heals_transient_fault():
    inj = FaultInjector({"io": [0, 1]})
    tel = obs.Telemetry()
    sleeps = []

    def fn():
        inj.check("io")
        return "ok"

    out = retry_with_backoff(fn, RetryPolicy(attempts=3, backoff_s=0.01),
                             site="io", tel=tel, sleep=sleeps.append)
    assert out == "ok"
    assert inj.count("io") == 3
    assert sleeps == [0.01, 0.02]               # exponential backoff
    assert tel.counters["faults.retries"] == 2
    assert tel.counters["faults.retries.io"] == 2


def test_retry_exhaustion_reraises_last_error():
    inj = FaultInjector({"io": [0, 1, 2]})
    with pytest.raises(InjectedFault):
        retry_with_backoff(lambda: inj.check("io"),
                           RetryPolicy(attempts=3, backoff_s=0.0),
                           site="io", sleep=lambda s: None)
    assert inj.count("io") == 3                 # exactly `attempts` tries


def test_retry_does_not_catch_nonretryable():
    calls = []

    def fn():
        calls.append(1)
        raise KeyError("not transient")

    with pytest.raises(KeyError):
        retry_with_backoff(fn, RetryPolicy(attempts=5, backoff_s=0.0),
                           sleep=lambda s: None)
    assert len(calls) == 1


def test_retry_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_s=-1.0)


# -- FaultConfig plumbing ----------------------------------------------------

def test_of_reads_config_attribute():
    fc = FaultConfig(retry=RetryPolicy())
    cfg = core.NeurLZConfig(faults=fc)
    assert of(cfg) is fc
    assert of(core.NeurLZConfig()) is DEFAULT
    assert of(object()) is DEFAULT


def test_degradability_classification():
    assert is_degradable(InjectedFault("s", 0))
    assert is_degradable(MemoryError())
    assert is_degradable(FloatingPointError())
    assert is_degradable(RuntimeError("RESOURCE_EXHAUSTED: out of memory"))
    assert not is_degradable(TypeError("a genuine bug"))
    assert degrade_reason(None) == "non-finite-loss"
    assert degrade_reason(InjectedFault("s", 0)) == "injected"
    assert degrade_reason(MemoryError()) == "error:MemoryError"


def test_fault_config_run_probe_inside_retry():
    """The injection probe sits inside the retried closure, so a transient
    planned fault heals on retry like a real transient error."""
    fc = FaultConfig(injector=FaultInjector({"reader.load": 0}),
                     retry=RetryPolicy(attempts=2, backoff_s=0.0))
    assert fc.run(lambda: 42, site="reader.load") == 42
    # without a retry policy the same plan is fatal
    fc2 = FaultConfig(injector=FaultInjector({"reader.load": 0}))
    with pytest.raises(InjectedFault):
        fc2.run(lambda: 42, site="reader.load")


# -- AsyncArchiveWriter error semantics (regression) -------------------------

def _writer(sink, faults):
    return AsyncArchiveWriter(sink, core.NeurLZConfig(epochs=1),
                              faults=faults, queue_size=2)


def test_writer_failure_is_sticky_and_close_aborts(tmp_path):
    """A failed writer thread must (a) re-raise from every later call with
    the original cause chained, and (b) never seal a footer over the bad
    byte stream — the pre-PR-8 bug cleared the error and finalized."""
    p = os.fspath(tmp_path / "bad.nlz")
    w = _writer(p, FaultConfig(injector=FaultInjector({"writer.add_entry":
                                                       [0, 1, 2, 3]})))
    w.put_entry("a", {"conv": {"blob": b"x" * 16}})
    with pytest.raises(RuntimeError, match="writer thread failed") as ei:
        w.drain()
    assert isinstance(ei.value.__cause__, InjectedFault)
    with pytest.raises(RuntimeError):           # sticky: same failure again
        w.put_entry("b", {"conv": {"blob": b"y"}})
    with pytest.raises(RuntimeError):
        w.close({"field_order": ["a"]})
    # no footer: the container does not open as sealed
    with pytest.raises(A.CorruptArchiveError):
        A.ArchiveReader(p).close()
    scan = A.scan_container(p)
    assert not scan["sealed"] and scan["entries"] == {}


def test_writer_retry_heals_and_leaves_no_torn_bytes(tmp_path):
    p = os.fspath(tmp_path / "healed.nlz")
    inj = FaultInjector({"writer.add_entry": 1})
    w = _writer(p, FaultConfig(injector=inj,
                               retry=RetryPolicy(attempts=3, backoff_s=0.0)))
    w.put_entry("a", {"conv": {"blob": b"x" * 16}})
    w.put_entry("b", {"conv": {"blob": b"y" * 16}})
    stats = w.close({"field_order": ["a", "b"]})
    assert stats["entries"] == 2
    assert inj.hits == [("writer.add_entry", 1)]
    rep = A.verify_container(p)
    assert rep["sealed"] and rep["ok"]
    with A.ArchiveReader(p) as r:
        assert r.read_entry("b")["conv"]["blob"] == b"y" * 16


def test_writer_abort_after_failure_is_clean(tmp_path):
    p = os.fspath(tmp_path / "aborted.nlz")
    w = _writer(p, FaultConfig(injector=FaultInjector({"writer.add_entry":
                                                       0})))
    w.put_entry("a", {"conv": {"blob": b"x"}})
    w.abort()                                   # error path: no footer, no raise
    assert not A.scan_container(p)["sealed"]


# -- graceful degradation across engines -------------------------------------

def _degrade_cfg(engine: str) -> core.NeurLZConfig:
    # fresh injector per run: invocation counts are stateful
    fc = FaultConfig(injector=FaultInjector({"train.f1": 0}))
    return core.NeurLZConfig(epochs=1, mode="strict", engine=engine,
                             group_size=1, faults=fc)


def test_degraded_entries_byte_identical_across_engines():
    fields = _snapshot()
    arcs = {}
    for engine in ("serial", "batched"):
        arcs[engine] = core.compress(fields, rel_eb=1e-3,
                                     config=_degrade_cfg(engine))
    buf = io.BytesIO()
    streaming.compress(fields, buf, 1e-3, config=_degrade_cfg("streaming"))
    buf.seek(0)
    with A.ArchiveReader(buf) as r:
        arcs["streaming"] = core.assemble_streaming_archive(r)

    blobs = {k: A.dumps(v["fields"]) for k, v in arcs.items()}
    assert blobs["serial"] == blobs["batched"] == blobs["streaming"]
    for engine, arc in arcs.items():
        e = arc["fields"]["f1"]
        assert e["degraded"] == "injected", engine
        assert "weights" not in e and e["stats"] == []
        assert "degraded" not in arc["fields"]["f0"]
        assert arc["timing"]["degraded_fields"] == ["f1"], engine


def test_degraded_field_still_honors_error_bound():
    fields = _snapshot()
    cfg = _degrade_cfg("serial")
    arc = core.compress(fields, rel_eb=1e-3, config=cfg)
    dec = core.decompress(arc)
    eb = arc["fields"]["f1"]["abs_eb"]
    err = np.abs(dec["f1"].astype(np.float64)
                 - fields["f1"].astype(np.float64))
    assert float(err.max()) <= eb
    # batched decode path takes the same degraded shortcut
    dec_b = core.decompress(arc, engine="batched")
    np.testing.assert_array_equal(dec_b["f1"], dec["f1"])


def test_degraded_aux_producer_keeps_consumers_identical():
    """A degraded field that feeds another field's cross-channel inputs
    must not perturb the consumer: aux inputs are conventional
    reconstructions, computed from the source regardless of enhancement."""
    fields = _snapshot()
    base = core.NeurLZConfig(epochs=1, mode="strict",
                             cross_field={"f2": ("f1",)})
    clean = core.compress(fields, rel_eb=1e-3, config=base)
    hurt = core.compress(fields, rel_eb=1e-3, config=core.NeurLZConfig(
        epochs=1, mode="strict", cross_field={"f2": ("f1",)},
        faults=FaultConfig(injector=FaultInjector({"train.f1": 0}))))
    assert A.dumps(hurt["fields"]["f2"]) == A.dumps(clean["fields"]["f2"])
    assert hurt["fields"]["f1"]["degraded"] == "injected"


def test_degrade_disabled_raises():
    fields = _snapshot(2)
    cfg = core.NeurLZConfig(epochs=1, faults=FaultConfig(
        injector=FaultInjector({"train.f1": 0}), degrade=False))
    with pytest.raises(InjectedFault):
        core.compress(fields, rel_eb=1e-3, config=cfg)


def test_degradation_counted_on_telemetry():
    tel = obs.Telemetry()
    cfg = core.NeurLZConfig(epochs=1, telemetry=tel, faults=FaultConfig(
        injector=FaultInjector({"train.*": 0})))
    fields = _snapshot(2)
    core.compress(fields, rel_eb=1e-3, config=cfg)
    assert tel.counters["faults.degraded"] == 2


# -- retry sites in the streaming pipeline / decode --------------------------

def test_streaming_reader_load_retry(tmp_path):
    fields = _snapshot(2)
    inj = FaultInjector({"reader.load": 0})
    tel = obs.Telemetry()
    cfg = core.NeurLZConfig(epochs=1, mode="strict", engine="streaming",
                            group_size=1, telemetry=tel,
                            faults=FaultConfig(
                                injector=inj,
                                retry=RetryPolicy(attempts=3,
                                                  backoff_s=0.0)))
    p = os.fspath(tmp_path / "s.nlz")
    streaming.compress(fields, p, 1e-3, config=cfg)
    assert inj.hits == [("reader.load", 0)]
    assert tel.counters["faults.retries.reader.load"] >= 1
    clean = stream_pipeline.compress_dict(fields, 1e-3,
                                    config=core.NeurLZConfig(
                                        epochs=1, mode="strict",
                                        engine="streaming", group_size=1))
    with A.ArchiveReader(p) as r:
        arc = core.assemble_streaming_archive(r)
    assert A.dumps(arc["fields"]) == A.dumps(clean["fields"])


def test_archive_decode_entry_retry(tmp_path):
    fields = _snapshot(2)
    sess = repro.NeurLZ(epochs=1, engine="streaming")
    p = os.fspath(tmp_path / "s.nlz")
    arc = sess.compress_to(fields, p, rel_eb=1e-3)
    want = arc.decode("f0")
    arc.close()
    fc = FaultConfig(injector=FaultInjector({"decode.entry": 0}),
                     retry=RetryPolicy(attempts=3, backoff_s=0.0))
    with repro.Archive.open(p) as arc2:
        arc2.faults = fc
        np.testing.assert_array_equal(arc2.decode("f0"), want)
    assert fc.injector.hits == [("decode.entry", 0)]
    # no retry policy: the injected fault surfaces
    fc2 = FaultConfig(injector=FaultInjector({"decode.entry": 0}))
    with repro.Archive.open(p) as arc3:
        arc3.faults = fc2
        with pytest.raises(InjectedFault):
            arc3.decode("f0")


# -- straggler watchdog ------------------------------------------------------

def test_straggler_watchdog_flags_slow_groups(tmp_path):
    tel = obs.Telemetry()
    cfg = core.NeurLZConfig(epochs=1, mode="strict", engine="streaming",
                            group_size=1, telemetry=tel,
                            faults=FaultConfig(straggler_deadline_s=1e-4))
    report = stream_pipeline.compress_dict(_snapshot(2), 1e-3, config=cfg)
    assert tel.counters.get("faults.stragglers", 0) >= 1
    assert report["timing"]["straggler_overruns"] >= 1


def test_watchdog_disarmed_by_default(tmp_path):
    report = stream_pipeline.compress_dict(
        _snapshot(2), 1e-3,
        config=core.NeurLZConfig(epochs=1, mode="strict",
                                 engine="streaming", group_size=1))
    assert "straggler_overruns" not in report["timing"]
