"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints are mesh-agnostic ({path: full array}); re-scaling a job is
``load -> param_pspecs(new_mesh) -> device_put`` — no format conversion.
Tested in ``tests/test_checkpoint.py`` by saving from a 1×1 mesh and
restoring onto 2×2 (and back) with bit-identical params.
"""
from __future__ import annotations

import jax

from . import sharding as sh


def reshard_to_mesh(tree, mesh):
    """Place a (host) param tree onto ``mesh`` with the standard rules."""
    abstract = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    specs = sh.param_pspecs(abstract, mesh)
    named = sh.to_named(specs, mesh)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), tree, named)


def rescale(ckpt_manager, step, params_template, opt_template, new_mesh):
    """Full elastic restart: checkpoint from any world size -> new mesh."""
    params, opt, meta = ckpt_manager.restore(step, params_template, opt_template)
    params = reshard_to_mesh(params, new_mesh)
    if opt is not None:
        opt = type(opt)(step=opt.step,
                        mu=reshard_to_mesh(opt.mu, new_mesh),
                        nu=reshard_to_mesh(opt.nu, new_mesh))
    return params, opt, meta
