"""Shared model primitives: norms, rotary embeddings, initializers.

All parameters are plain pytrees with a *naming convention* that the
sharding rules in ``repro.distributed.sharding`` pattern-match on:

    w_in   — [d_in, d_out] with d_out tensor-parallel      -> P(fsdp, tp)
    w_out  — [d_in, d_out] with d_in tensor-parallel       -> P(tp, fsdp)
    embed  — [vocab, d]                                     -> P(tp, fsdp)
    *_experts_* — [E, ...]                                  -> P(tp, fsdp, ...)
    scale/bias/1-D                                          -> replicated
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))                    # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, D/2]
    cos = jnp.cos(ang)[..., None, :]                             # [..., S, 1, D/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def head_rmsnorm(x, scale, eps: float = 1e-6):
    """QK-norm: RMS norm over the head dim (qwen3/gemma3 style)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda v: jax.nn.gelu(v, approximate=True),
            "relu": jax.nn.relu}[name]
