"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — mLSTM blocks
with an sLSTM every 6th position (paper-style interleave)  [arXiv:2405.04517;
unverified]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024, n_heads=4,
    n_kv_heads=4, d_ff=0, vocab_size=50304, act="gelu",
    xlstm_slstm_every=6, xlstm_proj_factor=4.0 / 3.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=6, d_model=64, n_heads=2,
                               n_kv_heads=2, vocab_size=256,
                               xlstm_slstm_every=3, dtype="float32")
