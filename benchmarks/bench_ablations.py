"""Paper Fig 4 ablations: residual vs direct learning, skipping vs plain
DNN, cross-field vs single-field — PSNR after equal training budgets."""
from __future__ import annotations

import time

from . import common
from repro import core
from repro.core import metrics
from repro.core import neurlz
from repro.data import fields as F


def run(full: bool = False):
    shape = (32, 48, 48) if full else (24, 40, 40)
    epochs = 40 if full else 30
    flds = F.make_fields("nyx", shape=shape, seed=2)
    target, aux = "temperature", "dark_matter_density"

    variants = {
        "neurlz": dict(learn_residual=True, skip=True,
                       cross_field={target: (aux,)}),
        "non_residual": dict(learn_residual=False, skip=True,
                             cross_field={target: (aux,)}),
        "non_skipping": dict(learn_residual=True, skip=False,
                             cross_field={target: (aux,)}),
        "sflz_single_field": dict(learn_residual=True, skip=True,
                                  cross_field={}),
    }
    base_sub = {target: flds[target], aux: flds[aux]}
    for label, kw in variants.items():
        sub = dict(base_sub) if kw.get("cross_field") else {target: flds[target]}
        cfg = core.NeurLZConfig(epochs=epochs, mode="relaxed", **kw)
        t0 = time.time()
        arc = neurlz.compress_impl(sub, rel_eb=1e-2, config=cfg)
        dec = neurlz.decompress_impl(arc)
        p = metrics.psnr(flds[target], dec[target])
        common.csv_row(f"fig4/{label}", (time.time() - t0) * 1e6,
                       f"psnr={p:.2f};epochs={epochs}")


if __name__ == "__main__":
    run()
