"""The paper's enhancer network: size claim, regulation range, ablations."""
import jax
import numpy as np

from repro.core import skipping_dnn as SD


def test_param_count_matches_paper_claim():
    """~3,000 params for the 10-layer single-field net (paper §3.2.2)."""
    cfg = SD.SkippingDNNConfig(c_in=1)
    params = SD.init_params(jax.random.PRNGKey(0), cfg)
    n = SD.param_count(params)
    assert 2500 <= n <= 3500, n


def test_cross_field_only_adds_input_channel_params():
    p1 = SD.init_params(jax.random.PRNGKey(0), SD.SkippingDNNConfig(c_in=1))
    p2 = SD.init_params(jax.random.PRNGKey(0), SD.SkippingDNNConfig(c_in=2))
    assert SD.param_count(p2) - SD.param_count(p1) == 9 * 4  # 3x3 conv, 4 ch


def test_regulated_output_in_unit_range():
    cfg = SD.SkippingDNNConfig(c_in=1, regulated=True)
    params = SD.init_params(jax.random.PRNGKey(1), cfg)
    x = np.random.default_rng(0).standard_normal((3, 40, 40, 1)).astype(np.float32) * 10
    out = np.asarray(SD.forward(params, x, regulated=True, skip=True))
    assert out.shape == (3, 40, 40, 1)
    # closed interval: sigmoid saturates to exactly 0/1 in fp32 for large
    # |z|, giving residuals of exactly ±eb — still within the 2x bound
    assert np.all(out >= -1.0) and np.all(out <= 1.0)


def test_unregulated_output_unbounded_head():
    cfg = SD.SkippingDNNConfig(c_in=1, regulated=False)
    params = SD.init_params(jax.random.PRNGKey(1), cfg)
    x = np.random.default_rng(0).standard_normal((2, 32, 32, 1)).astype(np.float32)
    out = np.asarray(SD.forward(params, x, regulated=False, skip=True))
    assert np.isfinite(out).all()


def test_arbitrary_hw_padding():
    cfg = SD.SkippingDNNConfig(c_in=1)
    params = SD.init_params(jax.random.PRNGKey(0), cfg)
    for hw in [(17, 23), (16, 16), (50, 33)]:
        x = np.zeros((1, *hw, 1), np.float32)
        out = SD.forward(params, x, regulated=True, skip=True)
        assert out.shape == (1, *hw, 1)


def test_non_skipping_variant_runs():
    cfg = SD.SkippingDNNConfig(c_in=1, skip=False)
    params = SD.init_params(jax.random.PRNGKey(0), cfg)
    x = np.zeros((1, 32, 32, 1), np.float32)
    out = SD.forward(params, x, regulated=True, skip=False)
    assert out.shape == (1, 32, 32, 1)
