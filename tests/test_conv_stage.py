"""Compressor registry + shared conventional stage.

Covers the registry contract (hard errors for unknown names/kinds, the old
``archive_nbytes`` fall-through regression, third-party registration) and
the conv-stage byte-identity matrix: batched group compression must produce
payloads byte-identical to the per-field path, for every built-in
compressor, across all three engines.
"""
import numpy as np
import pytest

from repro import compressors as C
from repro import core
from repro.compressors import registry
from repro.core import archive as arc_io
from repro.core import conv_stage


def smooth_field(shape, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape)
    for ax in range(len(shape)):
        x = np.cumsum(x, axis=ax)
    x /= max(np.abs(x).max(), 1e-9)
    return x.astype(dtype)


COMPRESSORS = ["szlike", "szlike-lorenzo", "zfplike"]


# ---------------------------------------------------------------------------
# Registry contract
# ---------------------------------------------------------------------------

def test_unknown_compressor_raises():
    with pytest.raises(ValueError, match="unknown compressor"):
        C.compress(smooth_field((8, 8)), 1e-3, compressor="nope")
    with pytest.raises(ValueError, match="unknown compressor"):
        conv_stage.ConvStage("nope", 1e-3)


def test_unknown_archive_kind_raises():
    """Regression: ``archive_nbytes`` used to silently fall through to the
    zfplike accounting for unknown kinds; both decode-side dispatches must
    hard-error now."""
    with pytest.raises(ValueError, match="unknown archive kind"):
        C.archive_nbytes({"kind": "mystery", "nbytes": 7})
    with pytest.raises(ValueError, match="unknown archive kind"):
        C.decompress({"kind": "mystery"})
    with pytest.raises(ValueError, match="unknown archive kind"):
        C.archive_nbytes({})    # no kind tag at all


def test_builtins_registered_with_capabilities():
    assert registry.names() == sorted(COMPRESSORS)
    for name in COMPRESSORS:
        entry = registry.get(name)
        assert entry.batchable
        assert entry.batch_supports(np.float32)
        assert entry.batch_supports(np.float64)
        assert not entry.batch_supports(np.int32)


def test_register_custom_compressor():
    """A third-party compressor is a registration, not a core edit."""

    def raw_compress(x, rel_eb=None, *, abs_eb=None, **kw):
        x = np.asarray(x)
        arc = {"kind": "rawcopy", "dtype": str(x.dtype),
               "shape": list(x.shape), "payload": x.tobytes(),
               "abs_eb": float(abs_eb if abs_eb is not None else 0.0)}
        return arc, x.copy()

    def raw_decompress(arc):
        return np.frombuffer(arc["payload"],
                             dtype=arc["dtype"]).reshape(arc["shape"]).copy()

    entry = registry.CompressorEntry(
        name="rawcopy", kind="rawcopy", compress=raw_compress,
        decompress=raw_decompress,
        archive_nbytes=lambda arc: len(arc["payload"]))
    registry.register(entry)
    try:
        x = smooth_field((6, 7))
        arc, rec = C.compress(x, abs_eb=0.0, compressor="rawcopy")
        assert np.array_equal(C.decompress(arc), x)
        assert C.archive_nbytes(arc) == x.nbytes
        # Duplicate names are rejected unless overwritten explicitly.
        with pytest.raises(ValueError, match="already registered"):
            registry.register(entry)
        # Not batchable -> the conv stage falls back per-field.
        stage = conv_stage.ConvStage("rawcopy", abs_eb=0.0)
        fields = {f"f{i}": smooth_field((6, 7), seed=i) for i in range(3)}
        out = stage.run(fields)
        assert set(out) == set(fields)
        assert stage.stats.calls == 3
        assert stage.stats.batched_fields == 0
        assert stage.stats.fallback_fields == 3
    finally:
        registry.unregister("rawcopy")
    with pytest.raises(ValueError, match="unknown archive kind"):
        C.archive_nbytes(arc)


def test_kind_conflict_rejected():
    bad = registry.CompressorEntry(
        name="szlike-impostor", kind="szlike",
        compress=lambda *a, **k: None, decompress=lambda a: None,
        archive_nbytes=lambda a: 0)
    with pytest.raises(ValueError, match="kind"):
        registry.register(bad)


# ---------------------------------------------------------------------------
# Conv-stage batched execution: byte-identity + dispatch accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", COMPRESSORS)
def test_stage_batched_byte_identical_to_per_field(comp):
    fields = {
        "a0": smooth_field((10, 12, 8), seed=0),
        "a1": smooth_field((10, 12, 8), seed=1),
        "a2": smooth_field((10, 12, 8), seed=2),
        "b0": smooth_field((10, 12, 8), seed=3, dtype=np.float64),
        "c0": smooth_field((9, 7), seed=4),
    }
    fields["a1"][2, 3, 4] = np.nan     # literal-escape path rides along
    batched = conv_stage.ConvStage(comp, 1e-3).run(fields)
    per_field = conv_stage.ConvStage(comp, 1e-3, batch=False).run(fields)
    for name in fields:
        arc_b, rec_b = batched[name]
        arc_p, rec_p = per_field[name]
        assert arc_io.dumps(arc_b) == arc_io.dumps(arc_p), name
        assert np.array_equal(rec_b, rec_p, equal_nan=True), name
        assert C.archive_nbytes(arc_b) == C.archive_nbytes(arc_p)


def test_stage_stats_group_accounting():
    fields = {f"f{i}": smooth_field((8, 10, 8), seed=i) for i in range(4)}
    fields["g64"] = smooth_field((8, 10, 8), seed=9, dtype=np.float64)
    fields["h2d"] = smooth_field((9, 7), seed=10)
    stage = conv_stage.ConvStage("szlike", 1e-3)
    stage.run(fields)
    st = stage.stats
    assert st.fields == 6
    assert st.groups == 3              # (f32 3-D) + (f64 3-D) + (f32 2-D)
    assert st.batched_fields == 4      # the four same-signature fields
    assert st.fallback_fields == 2     # singleton groups run per-field
    assert st.calls == 3               # 1 fused + 2 singles < 6 fields


# ---------------------------------------------------------------------------
# Engines x compressors byte-identity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", COMPRESSORS)
def test_engine_matrix_conv_payloads_identical(comp):
    """Serial/batched/streaming engines must emit byte-identical conventional
    payloads (and sizes) for the same snapshot — whichever conv-stage path
    (fused group or per-field fallback) compressed each field."""
    fields = {
        "a0": smooth_field((6, 10, 8), seed=0),
        "a1": smooth_field((6, 10, 8), seed=1),
        "a2": smooth_field((6, 10, 8), seed=2),
        "b0": smooth_field((6, 10, 8), seed=3, dtype=np.float64),
    }
    reference = None
    for engine in ("serial", "batched", "streaming"):
        cfg = core.NeurLZConfig(compressor=comp, epochs=1, mode="strict",
                                engine=engine,
                                cross_field={"a1": ("a0",)})
        arc = core.compress(fields, rel_eb=1e-3, config=cfg)
        convs = {n: arc_io.dumps(arc["fields"][n]["conv"]) for n in fields}
        sizes = {n: C.archive_nbytes(arc["fields"][n]["conv"])
                 for n in fields}
        stats = arc["timing"]["conv_stage"]
        assert stats["fields"] == len(fields)
        assert stats["calls"] < stats["fields"], engine
        if reference is None:
            reference = (convs, sizes)
        else:
            assert convs == reference[0], (comp, engine)
            assert sizes == reference[1], (comp, engine)
    # The per-field stage (conv_batch=False) agrees too.
    cfg0 = core.NeurLZConfig(compressor=comp, epochs=1, mode="strict",
                             engine="serial", conv_batch=False,
                             cross_field={"a1": ("a0",)})
    arc0 = core.compress(fields, rel_eb=1e-3, config=cfg0)
    assert {n: arc_io.dumps(arc0["fields"][n]["conv"])
            for n in fields} == reference[0]


# ---------------------------------------------------------------------------
# Property: mixed shapes/dtypes never break batched == per-field
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # hypothesis is an optional [dev] extra
    HAVE_HYPOTHESIS = False


def _mk_fields(seed: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    shapes = [(6, 8, 8), (5, 7), (6, 8, 8), (4, 9, 5)]
    out = {}
    for i in range(int(rng.integers(2, 5))):
        shape = shapes[int(rng.integers(0, len(shapes)))]
        dtype = np.float64 if (seed + i) % 3 == 0 else np.float32
        x = rng.standard_normal(shape)
        for ax in range(len(shape)):
            x = np.cumsum(x, axis=ax)
        out[f"f{i}"] = x.astype(dtype)
    return out


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000), eb=st.sampled_from([1e-2, 1e-3]),
           comp=st.sampled_from(COMPRESSORS))
    def test_property_stage_byte_identity(seed, eb, comp):
        fields = _mk_fields(seed)
        batched = conv_stage.ConvStage(comp, eb).run(fields)
        per_field = conv_stage.ConvStage(comp, eb, batch=False).run(fields)
        for name in fields:
            assert arc_io.dumps(batched[name][0]) \
                == arc_io.dumps(per_field[name][0])
            assert np.array_equal(batched[name][1], per_field[name][1])
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_stage_byte_identity():
        pass
