"""NeurLZ archive serialization (paper Fig. 2 bottom: file format).

Layout per field: conventional compressed payload ‖ enhancer weights
(dataset-precision floats, zstd'd) ‖ outlier coordinates (strict mode) ‖
normalization stats + header.  msgpack binary container, numpy arrays as
typed blobs.  ``nbytes`` accounting matches what lands on disk.

Two container formats, versioned side by side:

* **whole-dict** (original) — one msgpack blob for the entire archive dict
  (:func:`save` / :func:`load`).
* **streaming v1** — an append-able record container written incrementally
  by the streaming pipeline (:class:`ArchiveAppender`): an 8-byte magic,
  then length-prefixed msgpack records (one per field entry, in completion
  order), then an index footer record mapping field name → (offset, length)
  plus snapshot metadata, the footer's own offset, and the magic again as a
  trailer.  :class:`ArchiveReader` seeks the footer and decodes one field
  at a time, so a decoder never has to hold the whole archive in memory.
  Field *entries* are byte-identical to the whole-dict format's — only the
  container differs — and :func:`repro.core.load` sniffs the magic so both
  formats load through the same call.
"""
from __future__ import annotations

import io
import os
import struct

import msgpack
import numpy as np

from ..compressors import codec


def _default(obj):
    if isinstance(obj, np.ndarray):
        return {b"__nd__": True, b"dtype": str(obj.dtype), b"shape": list(obj.shape),
                b"data": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj)}")


def _hook(obj):
    if b"__nd__" in obj:
        return np.frombuffer(obj[b"data"], dtype=obj[b"dtype"]).reshape(obj[b"shape"]).copy()
    return obj


def dumps(obj) -> bytes:
    return msgpack.packb(obj, default=_default, use_bin_type=True)


def loads(data: bytes):
    return msgpack.unpackb(data, object_hook=_hook, raw=False, strict_map_key=False)


def save(path: str, obj) -> int:
    data = dumps(obj)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load(path: str):
    with open(path, "rb") as f:
        return loads(f.read())


# ---------------------------------------------------------------------------
# Streaming container (format v1): append-able records + index footer
# ---------------------------------------------------------------------------

STREAM_MAGIC = b"NLZSTRM1"
_LEN = struct.Struct("<Q")


def is_streaming_archive(path_or_bytes) -> bool:
    """Sniff the streaming-container magic (path or leading bytes)."""
    if isinstance(path_or_bytes, (bytes, bytearray)):
        return bytes(path_or_bytes[:8]) == STREAM_MAGIC
    try:
        with open(path_or_bytes, "rb") as f:
            return f.read(8) == STREAM_MAGIC
    except (OSError, TypeError):
        return False


class ArchiveAppender:
    """Incremental streaming-archive writer.

    ``append``/``add_entry`` write length-prefixed msgpack records as they
    arrive (the async writer thread calls this one entry at a time);
    ``finalize`` seals the container with the index footer.  ``sink`` is a
    path or a binary file object.
    """

    def __init__(self, sink):
        self._own = isinstance(sink, (str, bytes, os.PathLike))
        self._f = open(sink, "wb") if self._own else sink
        self._f.write(STREAM_MAGIC)
        self._offset = len(STREAM_MAGIC)
        self.entries: dict[str, list[int]] = {}   # name -> [offset, length]
        self.bytes_written = self._offset

    def append(self, obj) -> tuple[int, int]:
        data = dumps(obj)
        off = self._offset
        self._f.write(_LEN.pack(len(data)))
        self._f.write(data)
        self._offset += _LEN.size + len(data)
        self.bytes_written = self._offset
        return off, len(data)

    def add_entry(self, name: str, entry: dict) -> None:
        off, ln = self.append({"name": name, "entry": entry})
        self.entries[name] = [off, ln]

    def finalize(self, meta: dict) -> int:
        """Write the index footer; returns total container bytes."""
        footer = {"version": 1, "meta": meta, "entries": self.entries}
        foff, _ = self.append(footer)
        self._f.write(_LEN.pack(foff))
        self._f.write(STREAM_MAGIC)
        self._offset += _LEN.size + len(STREAM_MAGIC)
        self.bytes_written = self._offset
        self._f.flush()
        if self._own:
            self._f.close()
        return self._offset

    def abort(self) -> None:
        """Close without a footer (error path); the file stays sniffable as
        a streaming archive but unreadable — by design, half-written
        snapshots must not decode silently."""
        if self._own:
            self._f.close()


class ArchiveReader:
    """Random-access reader for the streaming container.

    Decodes the index footer once, then ``read_entry(name)`` loads exactly
    one field's record from disk — the basis of one-field-at-a-time decode.
    ``entry_reads`` records every entry record pulled off disk, in order
    (the footer is not an entry) — the accounting that lets tests assert a
    lazy decode touched only one field's aux closure.
    """

    def __init__(self, source):
        self._own = isinstance(source, (str, bytes, os.PathLike))
        self._f = open(source, "rb") if self._own else source
        self._f.seek(0)
        if self._f.read(8) != STREAM_MAGIC:
            raise ValueError("not a NeurLZ streaming archive (bad magic)")
        self._f.seek(-(len(STREAM_MAGIC) + _LEN.size), io.SEEK_END)
        foff = _LEN.unpack(self._f.read(_LEN.size))[0]
        if self._f.read(8) != STREAM_MAGIC:
            raise ValueError("truncated NeurLZ streaming archive (no trailer)")
        footer = self._read_record(foff)
        self.version = footer["version"]
        self.meta = footer["meta"]
        self.entries = footer["entries"]
        self.entry_reads: list[str] = []

    def _read_record(self, offset: int):
        self._f.seek(offset)
        n = _LEN.unpack(self._f.read(_LEN.size))[0]
        return loads(self._f.read(n))

    def read_entry(self, name: str) -> dict:
        off, _ = self.entries[name]
        rec = self._read_record(off)
        if rec["name"] != name:
            raise ValueError(f"index points at {rec['name']!r}, not {name!r}")
        self.entry_reads.append(name)
        return rec["entry"]

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def pack_weights(params_tree, dtype: str = "float32") -> dict:
    """Flatten an enhancer param tree into one compressed blob (archive
    payload).  The codec name rides in the header so a zlib-only decoder can
    read archives written with zstd and vice versa."""
    import jax

    leaves, treedef = jax.tree.flatten(params_tree)
    arrs = [np.asarray(l, dtype=dtype) for l in leaves]
    buf = io.BytesIO()
    for a in arrs:
        buf.write(a.tobytes())
    payload, cname = codec.compress(buf.getvalue(), 9)
    return {
        "dtype": dtype,
        "shapes": [list(a.shape) for a in arrs],
        "payload": payload,
        "codec": cname,
        "nbytes": len(payload),
        "raw_nbytes": sum(a.nbytes for a in arrs),
        "n_params": sum(a.size for a in arrs),
    }


def unpack_weights(blob: dict, params_like) -> object:
    """Inverse of :func:`pack_weights`, restored into ``params_like`` tree."""
    import jax
    import jax.numpy as jnp

    raw = codec.decompress(blob["payload"], blob.get("codec", "zstd"))
    leaves, treedef = jax.tree.flatten(params_like)
    out, off = [], 0
    dt = np.dtype(blob["dtype"])
    for leaf, shape in zip(leaves, blob["shapes"]):
        n = int(np.prod(shape)) * dt.itemsize
        arr = np.frombuffer(raw[off:off + n], dtype=dt).reshape(shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
