"""The ``Archive`` handle: lazy random-access decode over the streaming
container, dict-format wrapping, format sniffing, legacy ``core.load``
routing, and the symmetric batched conventional decode.

The lazy-decode assertions use the :class:`ArchiveReader.entry_reads`
accounting: opening a streaming container must read *no* entry records
(footer only), and ``decode(field)`` must read exactly that field's entry
plus its cross-field aux closure.
"""
import io

import numpy as np
import pytest

import repro
from repro import core, streaming
from repro.core import archive as A
from repro.core.archive_api import Archive
from repro.data import fields as F

FIELDS = F.make_fields("nyx", shape=(8, 16, 16), seed=7)
NAMES = list(FIELDS)
CROSS = {NAMES[0]: (NAMES[1],), NAMES[2]: (NAMES[1],)}


def _cfg(engine="serial", **kw):
    return core.NeurLZConfig(epochs=2, mode="strict", engine=engine, **kw)


@pytest.fixture(scope="module")
def stream_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("arc") / "snap.nlzs")
    streaming.compress(FIELDS, path, rel_eb=1e-3,
                       config=_cfg("streaming", cross_field=CROSS))
    return path


@pytest.fixture(scope="module")
def serial_arc():
    return core.compress(FIELDS, rel_eb=1e-3,
                         config=_cfg(cross_field=CROSS))


@pytest.fixture(scope="module")
def serial_dec(serial_arc):
    return core.decompress(serial_arc)


# ---------------------------------------------------------------------------
# Lazy open + random-access decode accounting (the acceptance criterion)
# ---------------------------------------------------------------------------

def test_open_streaming_reads_no_entries(stream_path):
    with Archive.open(stream_path) as arc:
        assert arc.streaming
        assert arc.field_names == NAMES
        assert arc.reader.entry_reads == []      # footer only


def test_decode_reads_only_aux_closure(stream_path, serial_dec):
    target = NAMES[0]                            # has aux NAMES[1]
    with Archive.open(stream_path) as arc:
        out = arc.decode(target)
        assert set(arc.reader.entry_reads) == {target, NAMES[1]}
        assert np.array_equal(out, serial_dec[target])


def test_decode_no_aux_reads_single_entry(stream_path, serial_dec):
    target = NAMES[3]                            # no aux
    with Archive.open(stream_path) as arc:
        out = arc.decode(target)
        assert arc.reader.entry_reads == [target]
        assert np.array_equal(out, serial_dec[target])


def test_decode_sweep_does_not_pin_entries(stream_path):
    """A field-by-field decode sweep must stay O(field) resident: decode
    reads records transiently, while entry() is the explicit cache."""
    with Archive.open(stream_path) as arc:
        for n in NAMES:
            arc.decode(n)
        assert arc._entries == {}                # nothing pinned
        # explicit entry() access caches (one read, reused)
        arc.entry(NAMES[0])
        n_reads = len(arc.reader.entry_reads)
        arc.entry(NAMES[0])
        assert len(arc.reader.entry_reads) == n_reads
        assert NAMES[0] in arc._entries


# ---------------------------------------------------------------------------
# Full decode + engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ("serial", "batched"))
def test_decode_all_matches_serial(stream_path, serial_dec, engine):
    with Archive.open(stream_path) as arc:
        dec = arc.decode_all(engine=engine)
    assert set(dec) == set(NAMES)
    for n in NAMES:
        assert np.array_equal(dec[n], serial_dec[n]), (engine, n)


def test_dict_archive_wrapping(serial_arc, serial_dec):
    arc = Archive.from_dict(serial_arc)
    assert not arc.streaming
    assert arc.field_names == NAMES
    assert np.array_equal(arc.decode(NAMES[0]), serial_dec[NAMES[0]])
    assert arc.bitrate() == serial_arc["bitrate"]
    assert arc.bitrate(NAMES[0]) == serial_arc["bitrate"][NAMES[0]]
    assert arc["fields"] is serial_arc["fields"]
    assert Archive.from_dict(arc) is arc


# ---------------------------------------------------------------------------
# Dict-compat Mapping surface + bitrate parity
# ---------------------------------------------------------------------------

def test_streaming_mapping_compat(stream_path, serial_arc):
    with Archive.open(stream_path) as arc:
        assert arc["kind"] == "neurlz"
        assert arc["slice_axis"] == serial_arc["slice_axis"]
        assert arc["compressor"] == serial_arc["compressor"]
        assert set(arc) == {"kind", "fields", "slice_axis", "compressor",
                            "timing", "bitrate"}
        assert A.dumps(arc["fields"]) == A.dumps(serial_arc["fields"])
        assert arc["bitrate"] == serial_arc["bitrate"]
        # per-field bitrate without materializing everything
    with Archive.open(stream_path) as arc:
        br = arc.bitrate(NAMES[0])
        assert br == serial_arc["bitrate"][NAMES[0]]
        assert arc.reader.entry_reads == [NAMES[0]]


def test_bitrate_sweep_does_not_pin_entries(stream_path, serial_arc):
    """Whole-archive bitrate accounting must not leave every entry
    resident: each record is read transiently, sizes extracted, dropped."""
    with Archive.open(stream_path) as arc:
        assert arc.bitrate() == serial_arc["bitrate"]
        assert len(arc.reader.entry_reads) == len(NAMES)   # read once each
        assert arc._entries == {}                          # ...but not kept


def test_legacy_save_of_loaded_streaming_archive(tmp_path, stream_path,
                                                 serial_arc):
    """Regression: ``core.save(path, core.load(streaming_path))`` is the
    historical streaming -> whole-dict conversion; the lazy Archive handle
    must materialize through it instead of crashing msgpack."""
    arc = core.load(stream_path)
    p = str(tmp_path / "converted.nlz")
    n = core.save(p, arc)
    arc.close()
    assert n > 0
    reloaded = core.load(p)
    assert isinstance(reloaded, dict)          # whole-dict format on disk
    assert A.dumps(reloaded["fields"]) == A.dumps(serial_arc["fields"])


def test_core_load_streaming_is_lazy(stream_path, serial_arc):
    """The eager-load regression fix: ``core.load`` on a streaming
    container returns the lazy handle, not a fully reassembled dict."""
    arc = core.load(stream_path)
    assert isinstance(arc, Archive)
    assert arc.reader.entry_reads == []
    # ...while staying drop-in dict-compatible with PR 4 behavior:
    assert A.dumps(arc["fields"]) == A.dumps(serial_arc["fields"])
    dec = core.decompress(arc)
    ref = core.decompress(serial_arc)
    for n in NAMES:
        assert np.array_equal(dec[n], ref[n])
    arc.close()


# ---------------------------------------------------------------------------
# save / open round-trips
# ---------------------------------------------------------------------------

def test_save_roundtrip_dict(tmp_path, serial_arc):
    arc = Archive.from_dict(serial_arc)
    p = str(tmp_path / "snap.nlz")
    n = arc.save(p)
    reopened = Archive.open(p)
    assert not reopened.streaming
    assert n > 0
    assert A.dumps(reopened["fields"]) == A.dumps(serial_arc["fields"])


def test_save_roundtrip_streaming_is_byte_copy(tmp_path, stream_path):
    with Archive.open(stream_path) as arc:
        p = str(tmp_path / "copy.nlzs")
        n = arc.save(p)
        assert arc.reader.entry_reads == []      # no decode to copy
    assert open(p, "rb").read() == open(stream_path, "rb").read()
    assert n > 0


def test_open_from_file_object(stream_path, serial_arc):
    buf = io.BytesIO(open(stream_path, "rb").read())
    with Archive.open(buf) as arc:
        assert arc.streaming
        assert np.array_equal(arc.decode(NAMES[3]),
                              core.decompress(serial_arc)[NAMES[3]])
    buf2 = io.BytesIO(A.dumps(serial_arc))
    arc2 = Archive.open(buf2)
    assert not arc2.streaming


def test_open_file_object_at_eof(stream_path):
    """Regression: a handle left at EOF (e.g. just written through) must
    still sniff the format from the start."""
    buf = io.BytesIO(open(stream_path, "rb").read())
    buf.seek(0, io.SEEK_END)
    with Archive.open(buf) as arc:
        assert arc.streaming
        assert arc.field_names == NAMES


# ---------------------------------------------------------------------------
# Blocked archives: manifest-aware decode
# ---------------------------------------------------------------------------

def test_decode_reassembles_blocked_field(tmp_path):
    big = F.make_fields("nyx", shape=(16, 16, 16), seed=1)["temperature"]
    bsrc = streaming.BlockedSource(streaming.DictSource({"huge": big}),
                                   max_block_bytes=big.nbytes // 3)
    path = str(tmp_path / "blocked.nlzs")
    streaming.compress(bsrc, path, rel_eb=1e-3, config=_cfg("streaming"))
    ref = streaming.decompress(path)["huge"]
    with Archive.open(path) as arc:
        assert "huge" in arc.block_manifest
        out = arc.decode("huge")                 # manifest original name
        assert np.array_equal(out, ref)
        dec = arc.decode_all(engine="serial", reassemble=True)
        assert list(dec) == ["huge"]
        assert np.array_equal(dec["huge"], ref)


# ---------------------------------------------------------------------------
# Region-of-interest decode
# ---------------------------------------------------------------------------

def test_roi_decode_plain_field(stream_path, serial_dec):
    with Archive.open(stream_path) as arc:
        full = serial_dec[NAMES[3]]
        roi = (slice(2, 6), slice(1, 9), slice(None, None, 2))
        assert np.array_equal(arc.decode(NAMES[3], roi=roi), full[roi])
        # single slice + short tuples extend numpy-style
        assert np.array_equal(arc.decode(NAMES[3], roi=slice(1, 4)),
                              full[1:4])
        assert np.array_equal(arc.decode(NAMES[3], roi=(slice(0, 3),)),
                              full[0:3])


def test_roi_rejects_bad_specs(stream_path):
    with Archive.open(stream_path) as arc:
        with pytest.raises(TypeError):
            arc.decode(NAMES[3], roi=3)              # not a slice
        with pytest.raises(TypeError):
            arc.decode(NAMES[3], roi=(slice(0, 2), 1))
        with pytest.raises(ValueError):
            arc.decode(NAMES[3], roi=(slice(None),) * 9)


@pytest.fixture(scope="module")
def blocked_path(tmp_path_factory):
    big = F.make_fields("nyx", shape=(16, 16, 16), seed=1)["temperature"]
    bsrc = streaming.BlockedSource(streaming.DictSource({"huge": big}),
                                   max_block_bytes=big.nbytes // 3)
    path = str(tmp_path_factory.mktemp("roi") / "blocked.nlzs")
    streaming.compress(bsrc, path, rel_eb=1e-3, config=_cfg("streaming"))
    return path, big


def test_roi_blocked_reads_only_covering_blocks(blocked_path):
    path, big = blocked_path
    with Archive.open(path) as arc:
        man = arc.block_manifest["huge"]
        blocks = man["blocks"]
        assert len(blocks) >= 3
        # slab entirely inside the first block: later blocks never read
        # (ROI decode runs first so entry_reads only reflects it)
        b0_name, b0_lo, b0_hi = blocks[0]
        roi = (slice(b0_lo, b0_hi - 1), slice(2, 10))
        out = arc.decode("huge", roi=roi)
        touched = set(arc.reader.entry_reads)
        assert b0_name in touched
        assert all(bn not in touched for bn, _, _ in blocks[1:])
        ref = arc.decode("huge")
        assert np.array_equal(out, ref[roi])


def test_roi_blocked_spans_and_steps(blocked_path):
    path, big = blocked_path
    with Archive.open(path) as arc:
        ref = arc.decode("huge")
        for roi in [(slice(3, 13),),                 # crosses block edges
                    (slice(None, None, 3), slice(1, 8)),
                    (slice(12, 2, -2),),             # negative step
                    (slice(5, 5),)]:                 # empty selection
            out = arc.decode("huge", roi=roi)
            assert np.array_equal(out, ref[roi]), roi


# ---------------------------------------------------------------------------
# os.PathLike at the API boundary
# ---------------------------------------------------------------------------

def test_pathlib_round_trip(tmp_path, stream_path):
    import pathlib
    p = pathlib.Path(stream_path)
    with Archive.open(p) as arc:                     # open via PathLike
        assert arc.streaming
        copy = tmp_path / "copy.nlzs"                # save via PathLike
        n = arc.save(copy)
        assert n == copy.stat().st_size
    with Archive.open(copy) as arc2:
        assert arc2.field_names == NAMES


def test_compress_to_accepts_pathlike(tmp_path):
    sub = {n: FIELDS[n] for n in NAMES[:2]}
    sink = tmp_path / "direct.nlzs"                  # a pathlib.Path
    nlz = repro.NeurLZ(epochs=2, engine="streaming")
    arc = nlz.compress_to(sub, sink, rel_eb=1e-3)
    assert arc.streaming and sink.exists()
    assert np.array_equal(arc.decode(NAMES[0]),
                          Archive.open(str(sink)).decode(NAMES[0]))
    arc.close()


# ---------------------------------------------------------------------------
# Symmetric batched conventional decode (registry capability)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", ("szlike", "szlike-lorenzo", "zfplike"))
def test_decompress_many_bit_identical(comp):
    from repro.compressors import registry
    entry = registry.get(comp)
    assert entry.decode_batchable
    rng = np.random.default_rng(0)
    arcs = {}
    for i in range(3):
        x = np.cumsum(rng.standard_normal((6, 8, 8)),
                      axis=0).astype(np.float32)
        arcs[f"f{i}"] = entry.compress(x, 1e-3)[0]
    # odd one out: different shape never joins the stacked dispatch
    arcs["odd"] = entry.compress(
        np.cumsum(rng.standard_normal((5, 7)), axis=0).astype(np.float32),
        1e-3)[0]
    out = registry.decompress_many(arcs)
    for n, arc in arcs.items():
        assert np.array_equal(out[n], entry.decompress(arc)), (comp, n)


@pytest.mark.parametrize("comp", ("szlike", "szlike-lorenzo", "zfplike"))
def test_decompress_batched_returns_detached_arrays(comp):
    """Batched decode must not hand out views into the stacked [F, ...]
    array — a view would pin the whole group until its last field dies,
    defeating the streaming decoder's refcounted residency.  float64 is
    the trap (astype to the same dtype can be a no-op)."""
    from repro.compressors import registry
    entry = registry.get(comp)
    rng = np.random.default_rng(1)
    arcs = [entry.compress(np.cumsum(rng.standard_normal((6, 8, 8)), axis=0),
                           1e-3)[0] for _ in range(3)]
    for rec, arc in zip(entry.decompress_batched(arcs), arcs):
        assert rec.dtype == np.dtype(arc["dtype"])
        base = rec.base if rec.base is not None else rec
        # resident bytes for one field must be O(field), not O(group)
        assert base.nbytes <= 2 * rec.nbytes, comp


def test_scheduler_run_forwards_bounds(tmp_path):
    from repro.core.bounds import ErrorBound
    sub = {n: FIELDS[n] for n in NAMES[:2]}
    sched = streaming.PipelineScheduler(_cfg("streaming"))
    path = str(tmp_path / "sched.nlzs")
    sched.run(streaming.DictSource(sub), path, rel_eb=1e-3,
              bounds={NAMES[1]: ErrorBound(rel=1e-2, mode="relaxed")})
    with Archive.open(path) as arc:
        assert arc.entry(NAMES[0])["mode"] == "strict"
        assert arc.entry(NAMES[1])["mode"] == "relaxed"


def test_iter_decompress_uses_batched_conv_decode(stream_path, serial_dec,
                                                  monkeypatch):
    """iter_decompress routes conventional decodes through decompress_many
    (one call per step) and stays bit-identical."""
    from repro.compressors import registry
    calls = []
    orig = registry.decompress_many

    def spy(arcs, **kw):
        calls.append(sorted(arcs))
        return orig(arcs, **kw)

    monkeypatch.setattr(registry, "decompress_many", spy)
    for name, x in streaming.iter_decompress(stream_path):
        assert np.array_equal(x, serial_dec[name])
    assert calls, "conventional decode did not go through decompress_many"
