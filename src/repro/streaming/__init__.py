"""Streaming snapshot pipeline: bounded-memory chunked ingestion + async
archive writer.

Public API:
    compress / iter_decompress / decompress — out-of-core snapshot codec
    StreamConfig, ResidencyLedger           — scheduler knobs + accounting
    ChunkedFieldSource + implementations    — lazy snapshot inputs
    AsyncArchiveWriter                      — writer-thread archival
"""
from .pipeline import (PipelineScheduler, StreamConfig, ResidencyLedger,  # noqa: F401
                       compress, decompress, iter_decompress, order_groups)
from .source import (BlockedSource, ChunkedFieldSource, DictSource,
                     FieldMeta, FunctionSource, NpyDirSource, as_source,
                     synthetic_snapshot_source)  # noqa: F401
from .writer import AsyncArchiveWriter, EntryTask  # noqa: F401
