"""xLSTM blocks (Beck et al., 2024): chunked mLSTM + recurrent sLSTM.

mLSTM — matrix-memory cell with exponential input gates and sigmoid forget
gates, evaluated chunkwise like the SSD scan (parallel intra-chunk scores,
``lax.scan`` carrying (S [H,K,V], n [H,K], m [H]) across chunks) with
max-stabilized log-space gating.  Sub-quadratic: the long_500k decode cell
uses the O(1)-state decode path.

sLSTM — scalar-memory cell with *recurrent* gate connections (block-diagonal
per head); inherently sequential, so it runs as a ``lax.scan`` over time —
the paper's own characterization; kept exact rather than approximated.

Block layout follows the paper: mLSTM blocks are pre-up-projected (factor 2,
no separate FFN — the assigned config's ``d_ff=0``); sLSTM blocks carry a
post-FFN with proj factor 4/3.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def m_init(key, cfg, dtype):
    d = cfg.d_model
    du = int(2 * d)                      # up-projection factor 2
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up_in": dense_init(ks[0], d, 2 * du, dtype),        # [x_mlstm | z gate]
        "w_q_in": dense_init(ks[1], du, du, dtype),
        "w_k_in": dense_init(ks[2], du, du, dtype),
        "w_v_in": dense_init(ks[3], du, du, dtype),
        "w_if": dense_init(ks[4], du, 2 * h, dtype),           # input/forget gates
        "norm_scale": jnp.zeros((du,), dtype),
        "w_down_out": dense_init(ks[5], du, d, dtype),
    }


def _m_gates(p, cfg, xu):
    h = cfg.n_heads
    gates = (xu @ p["w_if"]).astype(jnp.float32)
    i_log = gates[..., :h]                                     # pre-activation
    f_log = jax.nn.log_sigmoid(gates[..., h:])                 # log f ∈ (−∞, 0)
    return i_log, f_log


def m_forward(p, cfg, x, chunk: int = 128):
    """x: [B, L, D] -> [B, L, D]; chunked parallel mLSTM."""
    bsz, L, d = x.shape
    h = cfg.n_heads
    up = x @ p["w_up_in"]
    xu, z = jnp.split(up, 2, axis=-1)
    du = xu.shape[-1]
    hd = du // h
    chunk = min(chunk, L)
    assert L % chunk == 0
    nc = L // chunk

    q = (xu @ p["w_q_in"]).reshape(bsz, L, h, hd).astype(jnp.float32) / np.sqrt(hd)
    k = (xu @ p["w_k_in"]).reshape(bsz, L, h, hd).astype(jnp.float32)
    v = (xu @ p["w_v_in"]).reshape(bsz, L, h, hd).astype(jnp.float32)
    i_log, f_log = _m_gates(p, cfg, xu)                        # [B,L,H]

    qc = q.reshape(bsz, nc, chunk, h, hd)
    kc = k.reshape(bsz, nc, chunk, h, hd)
    vc = v.reshape(bsz, nc, chunk, h, hd)
    ic = i_log.reshape(bsz, nc, chunk, h)
    fc = f_log.reshape(bsz, nc, chunk, h)
    fcum = jnp.cumsum(fc, axis=2)                              # [B,nc,cl,H]
    ftot = fcum[:, :, -1]

    def chunk_step(carry, inp):
        S, nvec, m = carry                                     # [B,H,K,V],[B,H,K],[B,H]
        qk, kk, vk, ik, fck, ftk = inp
        # log-weights: inter uses m + fcum_i ; intra uses fcum_i − fcum_j + i_j
        inter_log = fck + m[:, None]                           # [B,cl,H]
        intra_log = (fck[:, :, None, :] - fck[:, None, :, :]
                     + ik[:, None, :, :])                      # [B,i,j,H]
        idx = jnp.arange(qk.shape[1])
        causal = (idx[:, None] >= idx[None, :])[None, :, :, None]
        intra_log = jnp.where(causal, intra_log, -jnp.inf)
        m_new = jnp.maximum(ftk + m, jnp.max(jnp.max(intra_log, 2), 1))  # [B,H]
        m_i = jnp.maximum(inter_log, jnp.max(intra_log, 2))    # per-row stabilizer [B,cl,H]
        w_inter = jnp.exp(inter_log - m_i)                     # [B,cl,H]
        w_intra = jnp.exp(intra_log - m_i[:, :, None, :])      # [B,i,j,H]
        y_inter = jnp.einsum("blhk,bhkv,blh->blhv", qk, S, w_inter)
        scores = jnp.einsum("bihk,bjhk->bijh", qk, kk) * w_intra
        y_intra = jnp.einsum("bijh,bjhv->bihv", scores, vk)
        n_inter = jnp.einsum("blhk,bhk,blh->blh", qk, nvec, w_inter)
        n_intra = jnp.einsum("bijh,bjh->bih", scores, jnp.ones_like(ik))
        denom = jnp.maximum(jnp.abs(n_inter + n_intra), jnp.exp(-m_i))
        y = (y_inter + y_intra) / denom[..., None]
        # carry update in the new stabilizer frame
        wS = jnp.exp(ftk + m - m_new)                          # [B,H]
        wk = jnp.exp(ftk[:, None] - fck + ik - m_new[:, None])  # [B,cl,H]
        S = wS[:, :, None, None] * S + jnp.einsum("bjhk,bjhv,bjh->bhkv", kk, vk, wk)
        nvec = wS[:, :, None] * nvec + jnp.einsum("bjhk,bjh->bhk", kk, wk)
        return (S, nvec, m_new), y

    S0 = jnp.zeros((bsz, h, hd, hd), jnp.float32)
    n0 = jnp.zeros((bsz, h, hd), jnp.float32)
    m0 = jnp.full((bsz, h), -1e30, jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (qc, kc, vc, ic, fcum, ftot))
    _, ys = jax.lax.scan(chunk_step, (S0, n0, m0), inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, L, du).astype(x.dtype)
    y = rmsnorm(y, p["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_down_out"]


def m_init_cache(cfg, batch: int):
    h = cfg.n_heads
    du = int(2 * cfg.d_model)
    hd = du // h
    return {"S": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def m_decode_step(p, cfg, x, cache):
    bsz = x.shape[0]
    h = cfg.n_heads
    up = x @ p["w_up_in"]
    xu, z = jnp.split(up, 2, axis=-1)
    du = xu.shape[-1]
    hd = du // h
    xu1 = xu[:, 0]
    q = (xu1 @ p["w_q_in"]).reshape(bsz, h, hd).astype(jnp.float32) / np.sqrt(hd)
    k = (xu1 @ p["w_k_in"]).reshape(bsz, h, hd).astype(jnp.float32)
    v = (xu1 @ p["w_v_in"]).reshape(bsz, h, hd).astype(jnp.float32)
    i_log, f_log = _m_gates(p, cfg, xu[:, 0:1])
    i_log, f_log = i_log[:, 0], f_log[:, 0]                    # [B,H]
    m_new = jnp.maximum(f_log + cache["m"], i_log)
    wS = jnp.exp(f_log + cache["m"] - m_new)
    wi = jnp.exp(i_log - m_new)
    S = wS[:, :, None, None] * cache["S"] + jnp.einsum("bhk,bhv,bh->bhkv", k, v, wi)
    nvec = wS[:, :, None] * cache["n"] + k * wi[:, :, None]
    num = jnp.einsum("bhk,bhkv->bhv", q, S)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q, nvec)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(bsz, 1, du).astype(x.dtype)
    y = rmsnorm(y, p["norm_scale"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["w_down_out"], {"S": S, "n": nvec, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def s_init(key, cfg, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    dff = int(cfg.xlstm_proj_factor * d)
    ks = jax.random.split(key, 6)
    return {
        "w_gates_in": dense_init(ks[0], d, 4 * d, dtype),      # i,f,z,o pre-acts
        "r_gates": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32)
                    * (1.0 / np.sqrt(hd))).astype(dtype),      # recurrent, per head
        "norm_scale": jnp.zeros((d,), dtype),
        "w_ff_gate_in": dense_init(ks[2], d, dff, dtype),
        "w_ff_up_in": dense_init(ks[3], d, dff, dtype),
        "w_ff_down_out": dense_init(ks[4], dff, d, dtype),
    }


def s_forward(p, cfg, x):
    """Sequential sLSTM over time (exact recurrence), then gated FFN."""
    bsz, L, d = x.shape
    h = cfg.n_heads
    hd = d // h
    wx = (x @ p["w_gates_in"]).reshape(bsz, L, h, 4 * hd)

    def step(carry, wxt):
        c, n, m, hprev = carry                                 # [B,H,hd] except m
        rec = jnp.einsum("bhk,hkg->bhg", hprev, p["r_gates"].astype(jnp.float32))
        g = wxt.astype(jnp.float32) + rec
        ig, fg, zg, og = jnp.split(g, 4, axis=-1)              # [B,H,hd]
        m_new = jnp.maximum(fg + m, ig)
        i = jnp.exp(ig - m_new)
        f = jnp.exp(fg + m - m_new)
        c = f * c + i * jnp.tanh(zg)
        n = f * n + i
        hh = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, hh), hh

    zeros = jnp.zeros((bsz, h, hd), jnp.float32)
    carry0 = (zeros, zeros, jnp.full((bsz, h, hd), -1e30, jnp.float32), zeros)
    _, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(bsz, L, d).astype(x.dtype)
    y = rmsnorm(y, p["norm_scale"], cfg.norm_eps)
    g = jax.nn.gelu(y @ p["w_ff_gate_in"], approximate=True)
    return (g * (y @ p["w_ff_up_in"])) @ p["w_ff_down_out"]


def s_init_cache(cfg, batch: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    zeros = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": zeros, "n": zeros,
            "m": jnp.full((batch, h, hd), -1e30, jnp.float32), "h": zeros}


def s_decode_step(p, cfg, x, cache):
    bsz = x.shape[0]
    h = cfg.n_heads
    hd = cfg.d_model // h
    wx = (x[:, 0] @ p["w_gates_in"]).reshape(bsz, h, 4 * hd)
    rec = jnp.einsum("bhk,hkg->bhg", cache["h"], p["r_gates"].astype(jnp.float32))
    g = wx.astype(jnp.float32) + rec
    ig, fg, zg, og = jnp.split(g, 4, axis=-1)
    m_new = jnp.maximum(fg + cache["m"], ig)
    i = jnp.exp(ig - m_new)
    f = jnp.exp(fg + cache["m"] - m_new)
    c = f * cache["c"] + i * jnp.tanh(zg)
    n = f * cache["n"] + i
    hh = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1.0)
    y = hh.reshape(bsz, 1, cfg.d_model).astype(x.dtype)
    y = rmsnorm(y, p["norm_scale"], cfg.norm_eps)
    gf = jax.nn.gelu(y @ p["w_ff_gate_in"], approximate=True)
    out = (gf * (y @ p["w_ff_up_in"])) @ p["w_ff_down_out"]
    return out, {"c": c, "n": n, "m": m_new, "h": hh}
