"""Bit-packed outlier coordinate codec (paper §3.3.1).

The paper stores, per outlier, the N-D coordinate using
``B̄ = Σ_i log2(dim_i)`` bits — i.e. the flat index in ``ceil(log2(Π dim_i))``
bits.  We pack flat indices at exactly that width (so the benchmark bitrate
accounting matches the paper's formula), delta-encoding sorted indices first
and letting zstd squeeze the packed stream further — a strictly-better rate
than the paper assumes, reported separately as ``packed_bits`` (paper formula)
vs ``nbytes`` (achieved).
"""
from __future__ import annotations

import math

import numpy as np

from . import codec


def coord_bits(shape: tuple[int, ...]) -> int:
    """``B̄`` from the paper: bits to address one point of ``shape``."""
    n = 1
    for d in shape:
        n *= int(d)
    return max(1, math.ceil(math.log2(max(n, 2))))


def _pack_bits(values: np.ndarray, width: int) -> bytes:
    """Pack ``values`` (uint64) at ``width`` bits each, little-endian bit order."""
    if values.size == 0:
        return b""
    bits = ((values[:, None] >> np.arange(width, dtype=np.uint64)) & 1).astype(np.uint8)
    return np.packbits(bits.ravel(), bitorder="little").tobytes()


def _unpack_bits(data: bytes, width: int, count: int) -> np.ndarray:
    if count == 0:
        return np.zeros((0,), dtype=np.uint64)
    bits = np.unpackbits(np.frombuffer(data, dtype=np.uint8), bitorder="little")
    bits = bits[: count * width].reshape(count, width).astype(np.uint64)
    return (bits << np.arange(width, dtype=np.uint64)).sum(axis=1)


def encode_outliers(mask: np.ndarray) -> dict:
    """Encode the True positions of a boolean mask."""
    shape = tuple(int(s) for s in mask.shape)
    flat = np.flatnonzero(np.asarray(mask).ravel()).astype(np.uint64)
    width = coord_bits(shape)
    # Delta encoding of sorted indices keeps the packed stream zstd-friendly.
    deltas = np.diff(flat, prepend=np.uint64(0)) if flat.size else flat
    packed = _pack_bits(deltas, width)
    payload, cname = codec.compress(packed, 9)
    return {
        "shape": list(shape),
        "count": int(flat.size),
        "width": width,
        "payload": payload,
        "codec": cname,
        # Paper-formula storage cost (bits): count * B̄.
        "packed_bits": int(flat.size) * width,
        "nbytes": len(payload),
    }


def decode_outliers(blob: dict) -> np.ndarray:
    shape = tuple(blob["shape"])
    packed = codec.decompress(blob["payload"], blob.get("codec", "zstd"))
    deltas = _unpack_bits(packed, blob["width"], blob["count"])
    flat = np.cumsum(deltas, dtype=np.uint64)
    mask = np.zeros(int(np.prod(shape)), dtype=bool)
    mask[flat.astype(np.int64)] = True
    return mask.reshape(shape)
