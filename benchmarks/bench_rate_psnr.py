"""Paper Fig 10: bitrate-vs-PSNR curves — conventional, SFLZ (single-field)
and NeurLZ (cross-field) for both compressor families."""
from __future__ import annotations

import time

from . import common
from repro.data import fields as F


def run(full: bool = False):
    shape = (48, 64, 64) if full else (24, 40, 40)
    epochs = 40 if full else 30
    flds = F.make_fields("nyx", shape=shape, seed=2)
    target, aux = "temperature", "dark_matter_density"
    bounds = [1e-2, 3e-3, 1e-3]
    for comp in ("szlike", "zfplike"):
        curve = common.rd_curve(flds[target], comp, bounds)
        for (p, b), eb in zip(curve, sorted(bounds, reverse=True)):
            common.csv_row(f"fig10/{comp}/conv/eb{eb:g}", 0.0,
                           f"psnr={p:.2f};bitrate={b:.3f}")
        for label, cf in (("sflz", {}), ("neurlz", {target: (aux,)})):
            sub = {target: flds[target]}
            if cf:
                sub[aux] = flds[aux]
            for eb in bounds:
                t0 = time.time()
                _, _, out, _ = common.run_neurlz(
                    sub, eb, compressor=comp, mode="strict", epochs=epochs,
                    cross_field=cf)
                r = out[target]
                common.csv_row(
                    f"fig10/{comp}/{label}/eb{eb:g}", (time.time() - t0) * 1e6,
                    f"psnr={r['psnr']:.2f};bitrate={r['bitrate']:.3f};"
                    f"bitrate_amortized={r['bitrate_amortized']:.3f}")


if __name__ == "__main__":
    run()
