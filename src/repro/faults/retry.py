"""Bounded retry with exponential backoff for transient pipeline faults.

The policy is a frozen dataclass so it rides inside configs the same way
``TelemetryConfig`` does; :func:`retry_with_backoff` is the single
executor, used by the archive writer thread, the streaming reader thread
and ``Archive.decode``.  Retries are counted on the run's telemetry
(``faults.retries`` and ``faults.retries.<site>``) so a run that healed
transient I/O errors says so in its summary.
"""
from __future__ import annotations

import dataclasses
import time

from ..obs import telemetry as obs_lib
from .injector import InjectedFault

__all__ = ["RetryPolicy", "retry_with_backoff"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """``attempts`` total tries (1 = fail fast), exponential backoff
    between them.  ``retry_on`` is the exception allowlist — everything
    else propagates on the first raise."""

    attempts: int = 3
    backoff_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 2.0
    retry_on: tuple = (OSError, InjectedFault)

    def __post_init__(self):
        if self.attempts < 1:
            raise ValueError("RetryPolicy.attempts must be >= 1")
        if self.backoff_s < 0 or self.max_backoff_s < 0:
            raise ValueError("RetryPolicy backoff must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("RetryPolicy.multiplier must be >= 1")


def retry_with_backoff(fn, policy: RetryPolicy | None = None, *,
                       site: str = "", tel=obs_lib.NULL, sleep=time.sleep):
    """Run ``fn()`` under ``policy``; re-raise the last failure once the
    attempt budget is spent.  ``sleep`` is injectable so tests assert the
    backoff sequence without waiting it out."""
    policy = policy if policy is not None else RetryPolicy()
    delay = policy.backoff_s
    for attempt in range(policy.attempts):
        try:
            return fn()
        except policy.retry_on:
            if attempt == policy.attempts - 1:
                raise
            tel.counter("faults.retries").add()
            if site:
                tel.counter(f"faults.retries.{site}").add()
            sleep(delay)
            delay = min(delay * policy.multiplier, policy.max_backoff_s)
