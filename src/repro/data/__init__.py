from . import fields, tokens  # noqa: F401
