"""Lowering dispatch for the three hot computations.

Every engine funnels its hot loops through one entry point per op —
``dnn_forward`` (the skipping-DNN conv chain), ``fused_enhance``
(enhance + regulate + outlier capture) and ``lorenzo`` (Lorenzo
predict/quantize) — selected by ``NeurLZConfig.lowering``:

* ``eager``  — the historical op-by-op path; the byte-level reference.
* ``jit``    — jit-compiled variants with *explicit bit-stable arithmetic*:
  contractions pinned via ``jax.lax`` ops at ``precision=HIGHEST`` and
  FMA-contraction suppressed (``jax.lax.optimization_barrier`` between the
  multiply and the add at every fused-multiply-add site), so the compiled
  path produces byte-identical archives.
* ``pallas`` — the hand-written TPU kernels in this package.
* ``auto``   — pallas where supported, else jit, else eager.

The contract is *verified, not assumed*: before a non-eager variant is used
it must pass its **parity probe** — a byte-for-byte comparison against the
eager reference on canary inputs (including adversarial rounding-boundary
values).  A variant that cannot honor the contract on this
(backend, dtype, shape-class) falls back to eager, and the fallback is
recorded (:func:`fallbacks`) so tests and telemetry can see it.  Probe
verdicts are cached per (op, lowering, backend, probe-key).

Backend identification is a process-wide cached probe
(:func:`backend`) instead of a per-call ``jax.default_backend()`` sniff;
tests force it with :func:`force_backend`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading

import jax

LOWERINGS = ("eager", "jit", "pallas", "auto")

# Preference order `auto` walks (first supported + probe-passing wins).
_AUTO_ORDER = ("pallas", "jit")

_lock = threading.Lock()
_forced_backend: str | None = None


@functools.lru_cache(maxsize=1)
def _default_backend() -> str:
    return jax.default_backend()


def backend() -> str:
    """The cached JAX backend name ('cpu' | 'gpu' | 'tpu').

    Cached once per process (the backend cannot change under JAX), unless a
    test is inside :func:`force_backend`.
    """
    return _forced_backend if _forced_backend is not None else _default_backend()


@contextlib.contextmanager
def force_backend(name: str):
    """Pretend the process runs on ``name`` for the duration of the block.

    Test hook: lets the parity-probe / fallback machinery be exercised for
    backends the box does not have.  Probe verdicts cached under the forced
    backend are dropped on exit so they cannot leak into real resolution.
    """
    global _forced_backend
    with _lock:
        prev, _forced_backend = _forced_backend, name
    try:
        yield
    finally:
        with _lock:
            _forced_backend = prev
            stale = [k for k in _verdicts if k[2] == name]
            for k in stale:
                del _verdicts[k]


@dataclasses.dataclass
class _Variant:
    fn: object
    probe: object | None = None      # () -> bool: byte-parity vs eager
    backends: tuple | None = None    # None = any backend


# op name -> {lowering: _Variant}
_ops: dict[str, dict[str, _Variant]] = {}
# (op, lowering, backend, key) -> bool
_verdicts: dict[tuple, bool] = {}
# (op, requested, chosen) -> count
_resolutions: dict[tuple, int] = {}
# [(op, lowering, backend, reason)] for every fallback decision
_fallbacks: list[tuple] = []


def register(op: str, lowering: str, fn, *, probe=None, backends=None) -> None:
    """Register a lowering variant for ``op``.

    ``probe`` is a zero-arg callable returning True iff the variant is
    byte-identical to the eager reference on this backend's canary inputs
    (it should *try to break* the variant — rounding-boundary values, odd
    shapes).  ``backends`` restricts the variant to those backend names.
    Registration happens at import time in the module that owns the
    implementation (skipping_dnn / regulation / szlike), so there are no
    import cycles through this module.
    """
    if lowering not in ("eager", "jit", "pallas"):
        raise ValueError(f"unknown lowering {lowering!r}")
    with _lock:
        _ops.setdefault(op, {})[lowering] = _Variant(
            fn=fn, probe=probe,
            backends=tuple(backends) if backends is not None else None)


def _probe_ok(op: str, lowering: str, var: _Variant, key=()) -> bool:
    if var.probe is None:
        return True
    vkey = (op, lowering, backend(), key)
    with _lock:
        if vkey in _verdicts:
            return _verdicts[vkey]
    try:
        ok = bool(var.probe())
    except Exception:   # a variant that cannot even run cannot be bit-stable
        ok = False
    with _lock:
        _verdicts[vkey] = ok
    return ok


def resolve(op: str, lowering: str = "auto", *, key=()):
    """Pick the implementation for ``op`` under ``lowering``.

    Returns ``(fn, chosen)`` where ``chosen`` names the lowering actually
    selected — ``"eager"`` whenever the requested one is unregistered,
    unsupported on this backend, or fails its parity probe.  ``key`` feeds
    the probe-verdict cache (callers pass a dtype/shape-class when parity
    depends on it).
    """
    if lowering not in LOWERINGS:
        raise ValueError(f"unknown lowering {lowering!r} (want one of "
                         f"{LOWERINGS})")
    variants = _ops.get(op)
    if not variants or "eager" not in variants:
        raise KeyError(f"op {op!r} has no registered eager reference")
    candidates = _AUTO_ORDER if lowering == "auto" else (lowering,)
    for cand in candidates:
        if cand == "eager":
            break
        var = variants.get(cand)
        if var is None:
            if lowering != "auto":
                _note_fallback(op, cand, "unregistered")
            continue
        if var.backends is not None and backend() not in var.backends:
            if lowering != "auto":
                _note_fallback(op, cand, f"backend {backend()!r} unsupported")
            continue
        if not _probe_ok(op, cand, var, key):
            _note_fallback(op, cand, "parity probe failed")
            continue
        _count(op, lowering, cand)
        return var.fn, cand
    _count(op, lowering, "eager")
    return variants["eager"].fn, "eager"


def _note_fallback(op, lowering, reason) -> None:
    with _lock:
        _fallbacks.append((op, lowering, backend(), reason))


def _count(op, requested, chosen) -> None:
    with _lock:
        k = (op, requested, chosen)
        _resolutions[k] = _resolutions.get(k, 0) + 1


def fallbacks() -> list[tuple]:
    """Every recorded ``(op, lowering, backend, reason)`` fallback."""
    with _lock:
        return list(_fallbacks)


def resolution_counts() -> dict[tuple, int]:
    """``(op, requested, chosen) -> count`` since process start."""
    with _lock:
        return dict(_resolutions)


def clear_cache() -> None:
    """Drop probe verdicts + fallback/resolution records (test isolation)."""
    with _lock:
        _verdicts.clear()
        _fallbacks.clear()
        _resolutions.clear()


def parity_report() -> dict:
    """Probe every registered non-eager variant on this backend.

    ``{op: {lowering: "ok" | "fallback (<reason>)"}}`` — the local parity
    check the README documents (`python -m repro.kernels.dispatch`).
    """
    report: dict = {}
    for op, variants in sorted(_ops.items()):
        report[op] = {}
        for low in ("jit", "pallas"):
            var = variants.get(low)
            if var is None:
                report[op][low] = "unregistered"
            elif var.backends is not None and backend() not in var.backends:
                report[op][low] = (f"fallback (backend {backend()!r} "
                                   "unsupported)")
            elif _probe_ok(op, low, var):
                report[op][low] = "ok"
            else:
                report[op][low] = "fallback (parity probe failed)"
    return report


def _register_all() -> None:
    """Import every module that registers variants (CLI/report helper)."""
    from ..compressors import szlike            # noqa: F401
    from ..core import regulation, skipping_dnn  # noqa: F401


if __name__ == "__main__":
    # Under ``python -m`` this file executes as ``__main__``, a *different*
    # module object from ``repro.kernels.dispatch`` — the one the op owners
    # register into.  Report through the canonical module, not this copy.
    from repro.kernels import dispatch as _dispatch

    _dispatch._register_all()
    print(f"backend: {_dispatch.backend()}")
    for op, rows in _dispatch.parity_report().items():
        for low, verdict in rows.items():
            print(f"{op:16s} {low:8s} {verdict}")
