"""Archive serving + transcode walkthrough (``repro.serve``).

Compresses a synthetic snapshot to a streaming container, then drives an
:class:`repro.ArchiveServer` against it: cold vs hot decode, a burst of
concurrent requests coalescing into one stacked dispatch, a ROI read, and
finally a :func:`repro.transcode` to cheaper bounds — everything under one
shared residency ledger.

    PYTHONPATH=src python examples/serve_archive.py
        [--shape 16,32,32] [--eb 1e-3] [--epochs 4]
        [--budget-mb 64] [--serve PATH]  # serve an existing container

With ``--serve PATH`` the synthetic-compress step is skipped and the
given container is served instead.
"""
import argparse
import os
import tempfile
import time

import repro
from repro.serve import ArchiveServer, transcode
from repro.streaming.pipeline import ResidencyLedger


def build_snapshot(path: str, shape, eb: float, epochs: int) -> None:
    from repro.data import fields as F
    flds = F.make_fields("nyx", shape=shape, seed=0)
    names = list(flds)
    nlz = repro.NeurLZ(epochs=epochs, engine="streaming",
                       cross_field={names[0]: (names[1],)})
    arc = nlz.compress_to(flds, path, rel_eb=eb)
    print(f"compressed {len(names)} fields -> {path} "
          f"({os.path.getsize(path)} bytes)")
    arc.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", default="16,32,32",
                    help="synthetic field shape (comma ints)")
    ap.add_argument("--eb", type=float, default=1e-3)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--budget-mb", type=int, default=64,
                    help="shared residency ceiling for cache + transcode")
    ap.add_argument("--serve", default=None, metavar="PATH",
                    help="serve this existing container instead of "
                         "compressing a synthetic snapshot")
    args = ap.parse_args()

    tmp = tempfile.mkdtemp(prefix="repro-serve-")
    path = args.serve or os.path.join(tmp, "snapshot.nlzs")
    if args.serve is None:
        shape = tuple(int(s) for s in args.shape.split(","))
        build_snapshot(path, shape, args.eb, args.epochs)

    tel = repro.Telemetry()
    ledger = ResidencyLedger(args.budget_mb << 20, telemetry=tel)
    with repro.Archive.open(path) as probe:
        names = list(probe.field_names)
    first = names[0]
    with ArchiveServer(path, ledger=ledger, telemetry=tel) as srv:

        t0 = time.perf_counter()
        x = srv.decode(first)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        srv.decode(first)
        hot = time.perf_counter() - t0
        print(f"cold decode {first!r}: {cold * 1e3:.1f} ms   "
              f"hot (cached): {hot * 1e3:.2f} ms")

        futs = [srv.submit(n) for n in names]       # concurrent burst
        for f in futs:
            f.result(60)
        st = srv.stats()
        print(f"burst of {len(names)} requests -> "
              f"{st['decode']['dispatches']} decode dispatches "
              f"(widest stacked: {st['decode']['max_width']})")

        roi = (slice(0, max(1, x.shape[0] // 2)),)
        slab = srv.decode(first, roi=roi)
        print(f"ROI {roi} -> shape {slab.shape} (full field {x.shape})")
        print(f"server stats: {st['counters']}, "
              f"resident {st['resident_bytes']} / {st['max_bytes']} B")

    cheap = os.path.join(tmp, "cheap.nlzs")
    out = transcode(path, cheap, rel_eb=args.eb * 10,
                    config=repro.NeurLZConfig(engine="streaming",
                                              epochs=args.epochs),
                    ledger=ledger, telemetry=tel)
    r1 = os.path.getsize(path)
    r2 = os.path.getsize(cheap)
    print(f"transcode to {args.eb * 10:g} rel bound: {r1} -> {r2} bytes "
          f"({r1 / max(r2, 1):.2f}x smaller), peak resident "
          f"{out.report['peak_resident_bytes']} B under the same ledger")
    out.close()


if __name__ == "__main__":
    main()
