"""Prediction-based error-bounded lossy compressor (SZ3-style), in JAX.

Two predictors, selectable per SZ3's design space:

* ``interp`` — multilevel spline interpolation (SZ3 default, Zhao et al.
  ICDE'21): reconstruct a coarse lattice first, then refine level by level,
  axis by axis, predicting every midpoint by cubic interpolation of already-
  reconstructed neighbors.  Each phase is a fully vectorized stencil — this is
  the TPU-native reformulation (DESIGN.md §3): within a level there are no
  sequential dependencies, so the whole phase is one fused jnp expression.

* ``lorenzo`` — cuSZ-style *dual-quantization* Lorenzo: pre-quantize the field
  onto the ``2*eb`` lattice, then take the 3-D first-order Lorenzo delta of
  the integer grid.  Both directions are pure stencils/prefix-sums (the
  sequential SZ1.4 recurrence is gone); the forward pass is the
  ``lorenzo3d`` Pallas kernel's oracle.

Both produce *real archives* (zstd-entropy-coded code streams + literal
escapes) with a hard error bound: |rec - x| <= eb for every finite point.

Determinism contract: compression and decompression share the exact same
reconstruction code path (same jnp ops on the same values), so the encoder's
``rec`` equals the decoder's output bit-for-bit — required for NeurLZ, whose
enhancer is trained against the encoder-side reconstruction.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from . import codec, entropy
from ..kernels import dispatch
from .quantize import CODE_CAP, abs_bound_from_rel

_INTERNAL = jnp.float64 if jnp.array(0.0, jnp.float64).dtype == jnp.float64 else jnp.float32


@dataclasses.dataclass(frozen=True)
class SZLikeConfig:
    predictor: str = "interp"  # "interp" | "lorenzo"
    max_level: int = 4         # interp: number of refinement levels
    zstd_level: int = 9
    # Shrink the internal bound slightly so the final cast back to the input
    # dtype cannot push a point past the user bound.
    eb_margin: float = 1e-9


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------

def _pad_to_lattice(x: np.ndarray, level: int) -> tuple[np.ndarray, tuple[int, ...]]:
    """Edge-pad every dim to ``D' ≡ 1 (mod 2^level)`` so all levels align."""
    s = 1 << level
    pads = []
    for d in x.shape:
        if d == 1:
            pads.append((0, 0))
        else:
            target = d if (d - 1) % s == 0 else ((d - 1) // s + 1) * s + 1
            pads.append((0, target - d))
    return np.pad(x, pads, mode="edge"), tuple(x.shape)


def _quantize_phase(values, pred, eb, out_dtype):
    """Fused quantize/reconstruct used by every phase (both directions).

    A point becomes a literal escape when (a) its code overflows, (b) it is
    non-finite, or (c) rounding the reconstruction to the *output dtype*
    would push it past the bound — (c) is what makes the bound hold exactly
    for fp32 fields even though internals run in fp64.
    """
    step = 2.0 * eb
    q = jnp.round((values - pred) / step)
    # non-finite *predictions* happen when a NaN literal sits among the
    # interpolation neighbors - escape those points too
    unpred = (jnp.abs(q) >= CODE_CAP) | ~jnp.isfinite(values) | ~jnp.isfinite(pred)
    codes = jnp.where(unpred, 0, q).astype(jnp.int32)
    rec = pred + codes.astype(pred.dtype) * step
    cast_bad = jnp.abs(rec.astype(out_dtype).astype(rec.dtype) - values) > eb
    unpred = unpred | cast_bad | ~jnp.isfinite(rec)
    codes = jnp.where(unpred, 0, codes)
    rec = jnp.where(unpred, values, rec)
    return codes, rec, unpred


def _encode_mask(mask: np.ndarray, level: int) -> dict:
    packed = np.packbits(mask.ravel())
    payload, cname = codec.compress(packed.tobytes(), level)
    return {"count": int(mask.size), "payload": payload, "codec": cname,
            "nbytes": len(payload)}


def _decode_mask(blob: dict) -> np.ndarray:
    raw = codec.decompress(blob["payload"], blob.get("codec", "zstd"))
    bits = np.unpackbits(np.frombuffer(raw, dtype=np.uint8))[: blob["count"]]
    return bits.astype(bool)


# ---------------------------------------------------------------------------
# interpolation predictor
# ---------------------------------------------------------------------------

def _phase_slicers(shape, axis, s):
    """Target/coarse slicers for one (level, axis) phase.

    Axes before ``axis`` are already refined to stride ``s//2`` this level;
    axes after are still at stride ``s``.
    """
    h = s // 2
    tgt, coarse = [], []
    for i, d in enumerate(shape):
        if d == 1:
            tgt.append(slice(0, 1))
            coarse.append(slice(0, 1))
        elif i < axis:
            tgt.append(slice(0, None, h))
            coarse.append(slice(0, None, h))
        elif i == axis:
            tgt.append(slice(h, None, s))
            coarse.append(slice(0, None, s))
        else:
            tgt.append(slice(0, None, s))
            coarse.append(slice(0, None, s))
    return tuple(tgt), tuple(coarse)


def _cubic_midpoint(coarse: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Cubic interpolation of midpoints from M+1 coarse points -> M preds.

    Interior midpoints use the 4-point cubic ``(-a + 9b + 9c - d) / 16``;
    the first/last fall back to linear — SZ3's boundary rule.
    """
    a = jnp.moveaxis(coarse, axis, 0)
    left1, right1 = a[:-1], a[1:]
    linear = 0.5 * (left1 + right1)
    m = a.shape[0] - 1  # number of midpoints
    if m >= 3:
        left2 = jnp.concatenate([a[:1], a[:-2]], axis=0)   # a[t-1] clamped
        right2 = jnp.concatenate([a[2:], a[-1:]], axis=0)  # a[t+2] clamped
        cubic = (-left2 + 9.0 * left1 + 9.0 * right1 - right2) / 16.0
        idx = jnp.arange(m).reshape((-1,) + (1,) * (a.ndim - 1))
        pred = jnp.where((idx == 0) | (idx == m - 1), linear, cubic)
    else:
        pred = linear
    return jnp.moveaxis(pred, 0, axis)


def _interp_schedule(shape: tuple[int, ...], max_level: int) -> tuple[int, list]:
    live = [d for d in shape if d > 1]
    if not live:
        return 1, []
    lmax = max(1, min(max_level, int(math.floor(math.log2(max(min(live) - 1, 2))))))
    phases = []
    for lev in range(lmax, 0, -1):
        s = 1 << lev
        for axis, d in enumerate(shape):
            if d > 1:
                phases.append((s, axis))
    return lmax, phases


def _interp_run(x: jnp.ndarray, eb: float, level: int, phases, mean: float,
                out_dtype=jnp.float32,
                codes_in: list | None = None, masks_in=None, lits_in=None):
    """Shared encode/decode walk.  Encode when ``codes_in is None``."""
    encode = codes_in is None
    # Coarsest lattice: predict the stored global mean.
    s0 = 1 << level
    init_slc = tuple(slice(0, 1) if d == 1 else slice(0, None, s0) for d in x.shape)
    rec = jnp.full(x.shape, jnp.asarray(mean, x.dtype), dtype=x.dtype)

    codes_out, masks_out, lits_out = [], [], []
    cursor = 0
    lit_cursor = 0

    def step(target_vals, pred, idx):
        nonlocal cursor, lit_cursor
        if encode:
            c, r, u = _quantize_phase(target_vals, pred, eb, out_dtype)
            codes_out.append(np.asarray(c).ravel())
            masks_out.append(np.asarray(u).ravel())
            lits_out.append(np.asarray(target_vals)[np.asarray(u)].ravel())
            return r
        n = int(np.prod(pred.shape))
        c = jnp.asarray(codes_in[cursor:cursor + n].reshape(pred.shape))
        un = masks_in[cursor:cursor + n].reshape(pred.shape)
        cursor += n
        r = pred + c.astype(pred.dtype) * (2.0 * eb)
        k = int(un.sum())
        if k:
            # Patch literal escapes (host-side scatter keeps it deterministic).
            lv = lits_in[lit_cursor:lit_cursor + k]
            lit_cursor += k
            rn = np.array(r)  # writable copy
            rn[un] = lv
            r = jnp.asarray(rn)
        return r

    # coarsest lattice points
    tvals = x[init_slc]
    pred0 = rec[init_slc]
    r0 = step(tvals, pred0, -1)
    rec = rec.at[init_slc].set(r0)

    for s, axis in phases:
        tgt, coarse = _phase_slicers(x.shape, axis, s)
        pred = _cubic_midpoint(rec[coarse], axis)
        if int(np.prod(pred.shape)) == 0:
            continue
        tvals = x[tgt]
        r = step(tvals, pred, axis)
        rec = rec.at[tgt].set(r)

    if encode:
        return rec, (np.concatenate(codes_out) if codes_out else np.zeros(0, np.int32),
                     np.concatenate(masks_out) if masks_out else np.zeros(0, bool),
                     np.concatenate(lits_out) if lits_out else np.zeros(0, np.asarray(x).dtype))
    return rec, None


def _interp_encode_batched(xs: jnp.ndarray, ebs: np.ndarray, level: int,
                           phases, means: np.ndarray, out_dtype):
    """Stacked-``[F, ...]`` mirror of :func:`_interp_run`'s encode branch.

    Runs the *same eager op sequence* as the per-field path with a leading
    field axis (per-field error bounds/means broadcast as ``[F, 1, ...]``).
    Elementwise jnp ops are bit-deterministic per element, so every field's
    slice of every phase equals the per-field run exactly — deliberately NOT
    jitted: fusing the float math can contract multiply-adds (FMA) and break
    the cross-engine byte-identity contract.

    Returns ``(rec [F, ...], [(codes, masks, lits)] per field)`` with the
    per-field streams concatenated in the per-field path's phase order.
    """
    nf = xs.shape[0]
    fshape = xs.shape[1:]
    bcast = (nf,) + (1,) * len(fshape)
    eb = jnp.asarray(np.asarray(ebs, np.float64).reshape(bcast))
    rec = jnp.broadcast_to(
        jnp.asarray(np.asarray(means, np.float64).reshape(bcast)).astype(xs.dtype),
        xs.shape)

    phase_codes, phase_masks, phase_lits = [], [], []

    def step(target_vals, pred):
        c, r, u = _quantize_phase(target_vals, pred, eb, out_dtype)
        un = np.asarray(u)
        vals = np.asarray(target_vals)
        phase_codes.append(np.asarray(c))
        phase_masks.append(un)
        # Extract each field's literal escapes now — retaining the full
        # target values until the end would pin an extra stacked-group copy.
        phase_lits.append([vals[f][un[f]].ravel() for f in range(nf)])
        return r

    s0 = 1 << level
    init_slc = (slice(None),) + tuple(
        slice(0, 1) if d == 1 else slice(0, None, s0) for d in fshape)
    r0 = step(xs[init_slc], rec[init_slc])
    rec = rec.at[init_slc].set(r0)

    for s, axis in phases:
        tgt, coarse = _phase_slicers(fshape, axis, s)
        tgt = (slice(None),) + tgt
        coarse = (slice(None),) + coarse
        pred = _cubic_midpoint(rec[coarse], axis + 1)
        if int(np.prod(pred.shape)) == 0:
            continue
        r = step(xs[tgt], pred)
        rec = rec.at[tgt].set(r)

    x_dtype = np.dtype(xs.dtype)
    streams = []
    for f in range(nf):
        codes = [c[f].ravel() for c in phase_codes]
        masks = [m[f].ravel() for m in phase_masks]
        lits = [pl[f] for pl in phase_lits]
        streams.append((
            np.concatenate(codes) if codes else np.zeros(0, np.int32),
            np.concatenate(masks) if masks else np.zeros(0, bool),
            np.concatenate(lits) if lits else np.zeros(0, x_dtype)))
    return np.asarray(rec), streams


def _interp_decode_batched(pad_shape, ebs: np.ndarray, level: int, phases,
                           means: np.ndarray, streams: list) -> np.ndarray:
    """Stacked-``[F, ...]`` mirror of :func:`_interp_run`'s decode branch.

    Same bit-stability discipline as :func:`_interp_encode_batched`: the
    exact per-field eager op sequence with a leading field axis and the
    per-field bounds/means broadcast as ``[F, 1, ...]`` — deliberately NOT
    jitted.  ``streams`` is the per-field ``(codes, masks, lits)`` decoded
    entropy streams; cursors advance in lockstep because every field shares
    the phase schedule.  Returns the stacked padded reconstruction.
    """
    nf = len(streams)
    bcast = (nf,) + (1,) * len(pad_shape)
    eb = jnp.asarray(np.asarray(ebs, np.float64).reshape(bcast))
    rec = jnp.broadcast_to(
        jnp.asarray(np.asarray(means, np.float64).reshape(bcast)).astype(_INTERNAL),
        (nf,) + tuple(pad_shape))

    cursor = 0
    lit_cursors = [0] * nf

    def step(pred):
        nonlocal cursor
        n = int(np.prod(pred.shape[1:]))
        c = jnp.asarray(np.stack(
            [streams[f][0][cursor:cursor + n].reshape(pred.shape[1:])
             for f in range(nf)]))
        un = np.stack(
            [streams[f][1][cursor:cursor + n].reshape(pred.shape[1:])
             for f in range(nf)])
        cursor += n
        r = pred + c.astype(pred.dtype) * (2.0 * eb)
        if un.any():
            rn = np.array(r)        # writable copy, host-side scatter
            for f in range(nf):
                k = int(un[f].sum())
                if k:
                    lv = streams[f][2][lit_cursors[f]:lit_cursors[f] + k]
                    lit_cursors[f] += k
                    rn[f][un[f]] = lv
            r = jnp.asarray(rn)
        return r

    s0 = 1 << level
    init_slc = (slice(None),) + tuple(
        slice(0, 1) if d == 1 else slice(0, None, s0) for d in pad_shape)
    r0 = step(rec[init_slc])
    rec = rec.at[init_slc].set(r0)

    for s, axis in phases:
        tgt, coarse = _phase_slicers(tuple(pad_shape), axis, s)
        tgt = (slice(None),) + tgt
        coarse = (slice(None),) + coarse
        pred = _cubic_midpoint(rec[coarse], axis + 1)
        if int(np.prod(pred.shape)) == 0:
            continue
        r = step(pred)
        rec = rec.at[tgt].set(r)
    return np.asarray(rec)


def decode_key(arc: dict) -> tuple:
    """Archives agreeing here may share one stacked decode dispatch (the
    registry ``decode_key`` capability).  Per-field error bounds are *not*
    part of the key — they broadcast along the stacked axis exactly as the
    encode side does, so one fused encode group always decodes fused too."""
    return (arc["predictor"], tuple(arc["shape"]), arc["dtype"],
            arc.get("level"), tuple(arc.get("pad_shape", ())))


def decompress_batched(arcs: list) -> list:
    """Decode a ``decode_key``-matched group as ONE stacked eager pass.

    Bit-identical to per-archive :func:`decompress` — the decode walk is
    elementwise per point, so running it with a leading ``[F]`` axis (codes
    stacked, per-field ``eb_int`` broadcast) reproduces every field's bits.
    """
    if not arcs:
        return []
    if any(a["kind"] != "szlike" for a in arcs):
        raise ValueError("not szlike archives")
    key = decode_key(arcs[0])
    if any(decode_key(a) != key for a in arcs):
        raise ValueError("decompress_batched needs decode_key-matched archives")
    nf = len(arcs)
    shape = tuple(arcs[0]["shape"])
    ebs = np.asarray([a["eb_int"] for a in arcs], np.float64)
    streams = [(entropy.decode_codes(a["codes"]).ravel(),
                _decode_mask(a["unpred"]),
                entropy.decode_floats(a["literals"]).ravel()) for a in arcs]

    if arcs[0]["predictor"] == "interp":
        pad_shape = tuple(arcs[0]["pad_shape"])
        level = arcs[0]["level"]
        _, phases = _interp_schedule(shape, level)
        means = np.asarray([a["mean"] for a in arcs], np.float64)
        rec = _interp_decode_batched(pad_shape, ebs, level, phases, means,
                                     streams)
        crop = tuple(slice(0, d) for d in shape)
        outs = [rec[f][crop] for f in range(nf)]
    else:
        d = jnp.asarray(np.stack(
            [streams[f][0].reshape(shape).astype(np.int32)
             for f in range(nf)]))
        q = lorenzo_undelta(d, axes=range(1, d.ndim))
        bcast = (nf,) + (1,) * len(shape)
        eb = jnp.asarray(ebs.reshape(bcast))
        rec = q.astype(_INTERNAL) * (2.0 * eb)
        out_all = np.array(rec)
        outs = []
        for f in range(nf):
            o = out_all[f]
            m = streams[f][1].reshape(shape)
            o[m] = streams[f][2]
            outs.append(o)
    # Always materialize per-field copies: the slices above are views into
    # the stacked [F, ...] array, and returning them would pin the whole
    # group's memory until the last field is dropped — defeating the
    # refcounted residency of the streaming decoder.  (astype with the
    # default copy=True detaches; same bits either way.)
    return [o.astype(np.dtype(a["dtype"])) for o, a in zip(outs, arcs)]


# ---------------------------------------------------------------------------
# Lorenzo (dual-quantization) predictor
# ---------------------------------------------------------------------------

def lorenzo_delta(q: jnp.ndarray, axes=None) -> jnp.ndarray:
    """N-D first-order Lorenzo delta of an integer lattice (zero boundary).

    Composition of first differences along every axis; exactly invertible by
    per-axis inclusive prefix sums in integer arithmetic.  ``axes`` restricts
    the differencing (the batched conv-stage passes ``range(1, ndim)`` so a
    stacked field axis is left alone); default is every axis.
    """
    d = q
    for axis in (range(q.ndim) if axes is None else axes):
        if q.shape[axis] == 1:
            continue
        shifted = jnp.concatenate(
            [jnp.zeros_like(jnp.take(d, jnp.arange(1), axis=axis)),
             jnp.take(d, jnp.arange(d.shape[axis] - 1), axis=axis)], axis=axis)
        d = d - shifted
    return d


def lorenzo_undelta(d: jnp.ndarray, axes=None) -> jnp.ndarray:
    q = d
    for axis in (range(d.ndim) if axes is None else axes):
        if d.shape[axis] == 1:
            continue
        q = jnp.cumsum(q, axis=axis, dtype=q.dtype)
    return q


# ---------------------------------------------------------------------------
# Lorenzo encode lowerings (repro.kernels.dispatch op "lorenzo")
# ---------------------------------------------------------------------------

def _lorenzo_encode_core(stacked, eb_arr, *, out_dtype: str):
    """Dual-quantization encode over a stacked ``[F, ...]`` group.

    The exact historical eager op sequence (prequant → escape detection →
    delta → reconstruction); per-field bounds broadcast as ``[F, 1, ...]``.
    Elementwise throughout and — deliberately — free of multiply-*add*
    chains (``rec`` is a bare ``codes * step`` product and the cast-check
    separates the product from the subtraction with dtype converts), so
    XLA has no FMA to contract and the jitted lowering below is
    byte-identical; the parity probe enforces rather than assumes this.
    Returns ``(delta int32, unpred bool, rec)``.
    """
    step = 2.0 * eb_arr
    q = jnp.round(stacked / step)
    unpred = (jnp.abs(q) >= CODE_CAP) | ~jnp.isfinite(stacked)
    qi = jnp.where(unpred, 0, q).astype(jnp.int32)
    rec = qi.astype(stacked.dtype) * step
    cast_bad = jnp.abs(rec.astype(jnp.dtype(out_dtype)).astype(rec.dtype)
                       - stacked) > eb_arr
    unpred = unpred | cast_bad
    qi = jnp.where(unpred, 0, qi)
    d = lorenzo_delta(qi, axes=range(1, qi.ndim))
    rec = jnp.where(unpred, stacked, qi.astype(stacked.dtype) * step)
    return d, unpred, rec


# Compiled variant: one dispatch per group instead of ~10 eager ops, input
# buffer donated (the stacked upload is dead after the call).  jax.jit's
# compile cache keys on (stacked shape, dtype, out_dtype, backend), so a
# snapshot's repeated same-shape groups compile once.
_lorenzo_encode_jit = functools.partial(
    jax.jit, static_argnames=("out_dtype",),
    donate_argnums=(0,))(_lorenzo_encode_core)


def lorenzo_jit_cache_size() -> int:
    """Compiled-variant cache entries (conv-stage stats / tests)."""
    return _lorenzo_encode_jit._cache_size()


def _lorenzo_jit_entry(stacked, eb_arr, *, out_dtype: str):
    with warnings.catch_warnings():
        # Donation is best-effort: XLA declines to alias when the input
        # stays live past its last read, and warns.  The decline is fine —
        # silence only that warning.
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _lorenzo_encode_jit(stacked, eb_arr, out_dtype=out_dtype)


def _lorenzo_jit_probe() -> bool:
    """Byte-parity canary for the compiled encode: ragged odd shape, values
    at quantization boundaries, a CODE_CAP overflow, a NaN and an
    fp32-cast borderline — everything that could round differently if the
    compiler re-associated or contracted the float ops."""
    rng = np.random.default_rng(12345)
    x = np.cumsum(rng.standard_normal((2, 5, 7, 3)), axis=1).astype(np.float32)
    x[0, 0, 0, 0] = np.nan
    x[0, 1, 2, 0] = 3.0e9            # CODE_CAP overflow at eb=1e-3
    x[1, 2, 3, 1] = np.float32(2 ** 25) + 0.5   # cast-rounding boundary
    xj = jnp.asarray(x)
    eb = jnp.asarray(np.array([1e-3, 2e-2]).reshape(2, 1, 1, 1))
    want = _lorenzo_encode_core(xj, eb, out_dtype="float32")
    got = _lorenzo_jit_entry(jnp.asarray(x), eb, out_dtype="float32")
    return all(np.asarray(w).tobytes() == np.asarray(g).tobytes()
               for w, g in zip(want, got))


def _lorenzo_pallas_entry(stacked, eb_arr, *, out_dtype: str):
    """``lorenzo3d`` Pallas kernel wrapper (TPU target).  The kernel fuses
    prequant+delta+rec but has no escape semantics (CODE_CAP overflow,
    non-finite, cast-rounding literals), so escapes are recomputed around
    it; the parity probe decides whether the composition is byte-exact."""
    from ..kernels import ops as kernel_ops
    outs_d, outs_u, outs_r = [], [], []
    ebs = np.asarray(eb_arr).reshape(stacked.shape[0])
    for f in range(stacked.shape[0]):
        d, rec = kernel_ops.lorenzo_quantize(stacked[f], float(ebs[f]))
        _, unpred, _ = _lorenzo_encode_core(
            stacked[f][None], eb_arr[f][None], out_dtype=out_dtype)
        outs_d.append(d)
        outs_u.append(unpred[0])
        outs_r.append(rec)
    return (jnp.stack(outs_d), jnp.stack(outs_u), jnp.stack(outs_r))


def _lorenzo_pallas_probe() -> bool:
    return _probe_against_eager(_lorenzo_pallas_entry)


def _probe_against_eager(candidate) -> bool:
    rng = np.random.default_rng(99)
    x = np.cumsum(rng.standard_normal((1, 6, 5, 4)), axis=1).astype(np.float32)
    x[0, 0, 0, 0] = 4.0e9            # escape: the kernel has no CODE_CAP
    xj = jnp.asarray(x)
    eb = jnp.asarray(np.array([1e-3]).reshape(1, 1, 1, 1))
    want = _lorenzo_encode_core(xj, eb, out_dtype="float32")
    got = candidate(xj, eb, out_dtype="float32")
    return all(np.asarray(w).tobytes() == np.asarray(g).tobytes()
               for w, g in zip(want, got))


dispatch.register("lorenzo", "eager", _lorenzo_encode_core)
dispatch.register("lorenzo", "jit", _lorenzo_jit_entry,
                  probe=_lorenzo_jit_probe)
dispatch.register("lorenzo", "pallas", _lorenzo_pallas_entry,
                  probe=_lorenzo_pallas_probe, backends=("tpu",))


def _lorenzo_encode(stacked, eb_arr, out_dtype, lowering: str):
    impl, _ = dispatch.resolve("lorenzo", lowering)
    return impl(stacked, eb_arr, out_dtype=str(np.dtype(out_dtype)))


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def compress(x: np.ndarray, rel_eb: float | None = None, *, abs_eb: float | None = None,
             config: SZLikeConfig = SZLikeConfig(),
             lowering: str = "auto") -> tuple[dict, np.ndarray]:
    """Compress ``x``; returns ``(archive, reconstruction)``.

    The reconstruction is exactly what :func:`decompress` will produce —
    NeurLZ trains its enhancer against it without a decode round-trip.

    ``lowering`` selects the Lorenzo quantize implementation through
    :mod:`repro.kernels.dispatch` (byte-identical archives either way — a
    variant that fails its parity probe falls back to eager).  The interp
    predictor is eager-only: its encode walks host-side entropy state
    between phases, so there is no jit variant to dispatch to.
    """
    x = np.asarray(x)
    if x.ndim not in (2, 3):
        raise ValueError(f"expected 2-D or 3-D field, got shape {x.shape}")
    orig_dtype = x.dtype
    if abs_eb is None:
        if rel_eb is None:
            raise ValueError("pass rel_eb or abs_eb")
        abs_eb = abs_bound_from_rel(x, rel_eb)
    eb_int = float(abs_eb) * (1.0 - config.eb_margin)

    work = x.astype(np.float64 if _INTERNAL == jnp.float64 else np.float32)
    finite = work[np.isfinite(work)]
    mean = float(finite.mean()) if finite.size else 0.0

    if config.predictor == "interp":
        level, phases = _interp_schedule(work.shape, config.max_level)
        padded, orig_shape = _pad_to_lattice(work, level)
        xj = jnp.asarray(padded)
        rec, (codes, masks, lits) = _interp_run(xj, eb_int, level, phases, mean,
                                                out_dtype=jnp.dtype(orig_dtype))
        rec_np = np.asarray(rec)[tuple(slice(0, d) for d in orig_shape)]
        arc = {
            "kind": "szlike", "predictor": "interp", "level": level,
            "shape": list(orig_shape), "pad_shape": list(padded.shape),
            "dtype": str(orig_dtype), "abs_eb": float(abs_eb), "eb_int": eb_int,
            "mean": mean,
            "codes": entropy.encode_codes(codes, config.zstd_level),
            "unpred": _encode_mask(masks, config.zstd_level),
            "literals": entropy.encode_floats(lits, config.zstd_level),
        }
    elif config.predictor == "lorenzo":
        # One-field "group": the stacked [1, ...] op sequence is bitwise
        # the per-field one (elementwise ops; the size-1 leading axis is
        # skipped by the delta), which is the conv stage's byte-identity
        # contract — and it shares the dispatch-lowered encode.
        xj = jnp.asarray(work)[None]
        eb_arr = jnp.asarray(
            np.asarray([eb_int], np.float64).reshape((1,) + (1,) * work.ndim))
        d, unpred, rec = _lorenzo_encode(xj, eb_arr, orig_dtype, lowering)
        un_np = np.asarray(unpred)[0]
        rec_np = np.asarray(rec)[0]
        lits = work[un_np]
        arc = {
            "kind": "szlike", "predictor": "lorenzo",
            "shape": list(work.shape), "dtype": str(orig_dtype),
            "abs_eb": float(abs_eb), "eb_int": eb_int, "mean": mean,
            "codes": entropy.encode_codes(np.asarray(d)[0], config.zstd_level),
            "unpred": _encode_mask(un_np.ravel(), config.zstd_level),
            "literals": entropy.encode_floats(lits, config.zstd_level),
        }
    else:
        raise ValueError(f"unknown predictor {config.predictor!r}")

    arc["nbytes"] = archive_nbytes(arc)
    return arc, rec_np.astype(orig_dtype, copy=False)


def compress_batched(xs, rel_eb: float | None = None, *,
                     abs_eb: float | None = None,
                     config: SZLikeConfig = SZLikeConfig(),
                     lowering: str = "auto") -> list:
    """Compress a group of same-shape/same-dtype fields in one stacked pass.

    The conv-stage batched entry point: the group's whole quantize +
    reconstruct runs as a single stacked-``[F, ...]`` op sequence (one
    device-op stream for the group instead of one per field); the host-side
    entropy stage stays per field.  Payloads are **byte-identical** to ``F``
    independent :func:`compress` calls — per-field bounds and means are
    derived exactly as the per-field path does and broadcast along the
    stacked axis.  Returns ``[(archive, reconstruction), ...]`` in order.

    ``lowering`` routes the stacked Lorenzo quantize through
    :mod:`repro.kernels.dispatch` exactly as :func:`compress` does —
    byte-identical payloads under every verdict.
    """
    arrs = [np.asarray(x) for x in xs]
    if not arrs:
        return []
    shape, dtype = arrs[0].shape, arrs[0].dtype
    if any(a.shape != shape or a.dtype != dtype for a in arrs):
        raise ValueError("compress_batched needs same-shape/same-dtype fields")
    if arrs[0].ndim not in (2, 3):
        raise ValueError(f"expected 2-D or 3-D fields, got shape {shape}")
    if abs_eb is None and rel_eb is None:
        raise ValueError("pass rel_eb or abs_eb")

    abs_ebs, eb_ints, means, works = [], [], [], []
    for a in arrs:
        ae = float(abs_eb) if abs_eb is not None else abs_bound_from_rel(a, rel_eb)
        abs_ebs.append(float(ae))
        eb_ints.append(float(ae) * (1.0 - config.eb_margin))
        w = a.astype(np.float64 if _INTERNAL == jnp.float64 else np.float32)
        finite = w[np.isfinite(w)]
        means.append(float(finite.mean()) if finite.size else 0.0)
        works.append(w)

    out = []
    if config.predictor == "interp":
        level, phases = _interp_schedule(shape, config.max_level)
        padded = [_pad_to_lattice(w, level)[0] for w in works]
        stacked = jnp.asarray(np.stack(padded))
        recs, streams = _interp_encode_batched(
            stacked, np.asarray(eb_ints), level, phases, np.asarray(means),
            jnp.dtype(dtype))
        crop = tuple(slice(0, d) for d in shape)
        for f in range(len(arrs)):
            codes, masks, lits = streams[f]
            arc = {
                "kind": "szlike", "predictor": "interp", "level": level,
                "shape": list(shape), "pad_shape": list(padded[f].shape),
                "dtype": str(dtype), "abs_eb": abs_ebs[f],
                "eb_int": eb_ints[f], "mean": means[f],
                "codes": entropy.encode_codes(codes, config.zstd_level),
                "unpred": _encode_mask(masks, config.zstd_level),
                "literals": entropy.encode_floats(lits, config.zstd_level),
            }
            arc["nbytes"] = archive_nbytes(arc)
            out.append((arc, recs[f][crop].astype(dtype, copy=False)))
    elif config.predictor == "lorenzo":
        stacked = jnp.asarray(np.stack(works))
        bcast = (len(arrs),) + (1,) * len(shape)
        eb_arr = jnp.asarray(np.asarray(eb_ints, np.float64).reshape(bcast))
        d, unpred, rec = _lorenzo_encode(stacked, eb_arr, dtype, lowering)
        d_np, un_np, rec_np = np.asarray(d), np.asarray(unpred), np.asarray(rec)
        for f in range(len(arrs)):
            lits = works[f][un_np[f]]
            arc = {
                "kind": "szlike", "predictor": "lorenzo",
                "shape": list(shape), "dtype": str(dtype),
                "abs_eb": abs_ebs[f], "eb_int": eb_ints[f], "mean": means[f],
                "codes": entropy.encode_codes(d_np[f], config.zstd_level),
                "unpred": _encode_mask(un_np[f].ravel(), config.zstd_level),
                "literals": entropy.encode_floats(lits, config.zstd_level),
            }
            arc["nbytes"] = archive_nbytes(arc)
            out.append((arc, rec_np[f].astype(dtype, copy=False)))
    else:
        raise ValueError(f"unknown predictor {config.predictor!r}")
    return out


def decompress(arc: dict) -> np.ndarray:
    if arc["kind"] != "szlike":
        raise ValueError("not an szlike archive")
    eb = arc["eb_int"]
    codes = entropy.decode_codes(arc["codes"]).ravel()
    masks = _decode_mask(arc["unpred"])
    lits = entropy.decode_floats(arc["literals"]).ravel()

    if arc["predictor"] == "interp":
        pad_shape = tuple(arc["pad_shape"])
        level = arc["level"]
        _, phases = _interp_schedule(tuple(arc["shape"]), level)
        dummy = jnp.zeros(pad_shape, dtype=_INTERNAL)
        rec, _ = _interp_run(dummy, eb, level, phases, arc["mean"],
                             codes_in=codes, masks_in=masks, lits_in=lits)
        out = np.array(rec)[tuple(slice(0, d) for d in arc["shape"])]
    else:
        d = jnp.asarray(codes.reshape(arc["shape"]).astype(np.int32))
        q = lorenzo_undelta(d)
        rec = q.astype(_INTERNAL) * (2.0 * eb)
        out = np.array(rec)
        m = masks.reshape(arc["shape"])
        out[m] = lits
    return out.astype(np.dtype(arc["dtype"]), copy=False)


def archive_nbytes(arc: dict) -> int:
    """Real archive size in bytes (payloads + small header estimate)."""
    n = 64  # header: shape/dtype/eb/mean/etc.
    for key in ("codes", "unpred", "literals"):
        if key in arc:
            n += arc[key]["nbytes"] + 16
    return n
