"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --preset reduced \\
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1 --ckpt-every 20

Features exercised here (the fault-tolerance story):
  * resume-from-latest on restart (identical data order via the
    checkpointable token stream),
  * atomic checkpointing with retention, optional NeurLZ-compressed weights,
  * straggler watchdog with early-checkpoint trigger,
  * deterministic failure injection (``--fail-at-step``) for restart drills,
  * optional compressed cross-pod grad sync when the mesh has a pod axis.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..checkpoint.checkpoint import CheckpointManager
from ..checkpoint.fault_tolerance import FailureInjector, StepWatchdog
from ..data.tokens import TokenStream
from ..models import model as M
from ..optim import warmup_cosine


def build(args):
    cfg = (configs.get_reduced(args.arch) if args.preset == "reduced"
           else configs.get_config(args.arch))
    model = M.build_model(cfg, model_axis=1)
    return cfg, model


def train(args) -> dict:
    cfg, model = build(args)
    params, opt_state = M.init_train_state(model, seed=args.seed)
    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir, keep=args.keep,
                             lossy_weights_eb=args.lossy_ckpt_eb)
    start_step = 0
    latest = ckpt.latest_step()
    if args.resume and latest is not None:
        params, opt_state, meta = ckpt.restore(latest, params, opt_state)
        stream.restore(meta["extra"]["stream"])
        start_step = latest
        print(f"[train] resumed from step {latest}")

    lr_fn = warmup_cosine(args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    step_fn = jax.jit(M.make_train_step(model, lr_fn=lr_fn,
                                        microbatch=args.microbatch))
    injector = FailureInjector(args.fail_at_step)
    want_early_ckpt = []
    watchdog = StepWatchdog(args.step_deadline,
                            on_straggler=lambda i: want_early_ckpt.append(i))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = {"tokens": jnp.asarray(stream.next_batch())}
        if cfg.family == "audio":
            batch = M.demo_batch(cfg, args.batch, args.seq, seed=step)
        elif cfg.family == "vlm":
            batch = M.demo_batch(cfg, args.batch,
                                 args.seq + cfg.frontend_tokens, seed=step)
        with watchdog.step(step):
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        injector.maybe_fail(step)
        if args.log_every and step % args.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if ((step + 1) % args.ckpt_every == 0 or step + 1 == args.steps
                or want_early_ckpt):
            want_early_ckpt.clear()
            ckpt.save(step + 1, params, opt_state,
                      extra={"stream": stream.checkpoint(),
                             "loss": loss})
    wall = time.time() - t0
    report = {
        "arch": args.arch, "steps": args.steps,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "wall_s": wall,
        "watchdog": watchdog.stats(),
        "resumed_from": start_step,
    }
    print(json.dumps(report, indent=1))
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=configs.ARCHS)
    ap.add_argument("--preset", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--resume", action="store_true", default=True)
    ap.add_argument("--lossy-ckpt-eb", type=float, default=None)
    ap.add_argument("--fail-at-step", type=int, default=None)
    ap.add_argument("--step-deadline", type=float, default=120.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    train(args)


if __name__ == "__main__":
    main()
