"""Compression-time online learning of the skipping enhancer (§3.2).

Dataset construction follows the paper exactly: a 3-D block is sliced along
one axis into single-channel images; the *input* is the normalized
decompressed slice (plus aux-field channels for cross-field learning) and the
*target* is the residual ``R = X − X'`` normalized by the error bound — which
lands in ``[−1, 1]`` by the compressor's bound guarantee, matching the
regulated Sigmoid head's range (balanced regulation, Fig. 6 Case B).

Normalization statistics are computed from the *decompressed* data only, so
the decoder can reproduce the identical input tensor without any side
information.

The whole epoch — shuffle, batch, Adam — runs inside one jitted
``lax.scan`` so online training costs one dispatch per epoch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..optim import adamw_init, adamw_update, cosine_schedule
from . import skipping_dnn


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    epochs: int = 100          # paper default
    batch: int = 10            # paper default
    lr: float = 1e-2           # paper default, cosine annealed
    min_lr_frac: float = 0.0
    seed: int = 0
    slice_axis: int = 0
    loss: str = "mse"          # "mse" | "l1"
    lowering: str = "auto"     # eager | jit | pallas | auto (kernel dispatch)


def normalize_stats(decomp: np.ndarray) -> tuple[float, float]:
    """Decoder-reproducible normalization constants (decompressed data only)."""
    d = np.asarray(decomp, dtype=np.float64)
    mu = float(d.mean())
    sd = float(d.std())
    return mu, sd if sd > 1e-30 else 1.0


def make_dataset(decomp: np.ndarray, orig: np.ndarray | None, eb: float,
                 aux: list[np.ndarray] | None = None, slice_axis: int = 0,
                 stats: list[tuple[float, float]] | None = None):
    """Slices -> (inputs [N,H,W,C], targets [N,H,W,1] | None, stats).

    ``orig=None`` builds inference inputs only (decoder side).  ``stats``
    lets the decoder reuse the encoder's stored constants byte-for-byte.
    """
    chans = [np.asarray(decomp)] + [np.asarray(a) for a in (aux or [])]
    if stats is None:
        stats = [normalize_stats(c) for c in chans]
    normed = []
    for c, (mu, sd) in zip(chans, stats):
        c = np.moveaxis(c.astype(np.float32), slice_axis, 0)
        normed.append((c - np.float32(mu)) / np.float32(sd))
    inputs = np.stack(normed, axis=-1)  # [N, H, W, C]
    targets = None
    if orig is not None:
        o = np.moveaxis(np.asarray(orig, dtype=np.float64), slice_axis, 0)
        d = np.moveaxis(np.asarray(decomp, dtype=np.float64), slice_axis, 0)
        targets = ((o - d) / eb).astype(np.float32)[..., None]  # in [-1, 1]
    return inputs, targets, stats


def epoch_batches(epoch_key, n: int, steps: int, batch: int):
    """The epoch's shuffled drop-last batch index matrix ``[steps, batch]``.

    Fresh shuffle each epoch (different tail every epoch) — traceable, shared
    verbatim by the serial per-epoch dispatch and the batched engine's fused
    whole-training dispatch so the sample order is identical in both."""
    perm = jax.random.permutation(epoch_key, n)[: steps * batch]
    return perm.reshape(steps, batch)


def batch_loss(params, xb, yb, *, regulated, skip, loss, lowering="auto"):
    """Mini-batch training loss — single definition for every engine."""
    pred = skipping_dnn.forward(params, xb, regulated=regulated, skip=skip,
                                lowering=lowering)
    if loss == "l1":
        return jnp.mean(jnp.abs(pred - yb))
    return jnp.mean(jnp.square(pred - yb))


def scan_train(params, opt_state, inputs, targets, batches, start_step, *,
               cfg_reg, cfg_skip, total_steps, base_lr, min_lr_frac, loss,
               lowering="auto"):
    """SGD scan over ``batches`` ``[S, batch]`` — the trace shared by the
    serial trainer (one epoch per dispatch) and the batched engine (every
    epoch of every field of a group in one dispatch).  Sharing the exact
    graph is what keeps the two engines bit-identical.  Returns per-step
    losses ``[S]``."""
    lr_fn = cosine_schedule(base_lr, total_steps, min_lr_frac)

    def loss_fn(p, xb, yb):
        return batch_loss(p, xb, yb, regulated=cfg_reg, skip=cfg_skip,
                          loss=loss, lowering=lowering)

    def body(carry, idx):
        p, s, step = carry
        xb = jnp.take(inputs, idx, axis=0)
        yb = jnp.take(targets, idx, axis=0)
        lval, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        lr = lr_fn(step)
        p, s = adamw_update(grads, s, p, lr=lr)
        return (p, s, step + 1), lval

    (params, opt_state, _), losses = jax.lax.scan(
        body, (params, opt_state, start_step), batches)
    return params, opt_state, losses


def epoch_core(params, opt_state, inputs, targets, epoch_key, start_step, *,
               cfg_reg, cfg_skip, batch, steps, total_steps, base_lr,
               min_lr_frac, loss, lowering="auto"):
    """One epoch of online learning for a single field."""
    batches = epoch_batches(epoch_key, inputs.shape[0], steps, batch)
    params, opt_state, losses = scan_train(
        params, opt_state, inputs, targets, batches, start_step,
        cfg_reg=cfg_reg, cfg_skip=cfg_skip, total_steps=total_steps,
        base_lr=base_lr, min_lr_frac=min_lr_frac, loss=loss,
        lowering=lowering)
    return params, opt_state, jnp.mean(losses)


_train_epoch = partial(jax.jit, static_argnames=(
    "cfg_reg", "cfg_skip", "batch", "steps", "total_steps", "base_lr",
    "min_lr_frac", "loss", "lowering"))(epoch_core)


def predict_graph(params, xs, *, regulated: bool, skip: bool,
                  batch: int = 64, lowering: str = "auto"):
    """Enhancer inference over all slices, chunked exactly like
    :func:`predict_residual` so both engines emit the same values; returns
    ``[N, H, W]``.  Traceable — the batched engine inlines one copy per field
    into a single dispatch."""
    outs = []
    for i in range(0, xs.shape[0], batch):
        out = skipping_dnn.forward(params, xs[i:i + batch],
                                   regulated=regulated, skip=skip,
                                   lowering=lowering)
        outs.append(out[..., 0])
    return jnp.concatenate(outs, axis=0) if len(outs) > 1 else outs[0]


def train(params, inputs: np.ndarray, targets: np.ndarray, cfg: TrainConfig,
          net_cfg: skipping_dnn.SkippingDNNConfig, opt_state=None,
          start_epoch: int = 0, epochs: int | None = None, on_epoch=None):
    """Run ``epochs`` (default cfg.epochs) of online learning.

    Returns ``(params, opt_state, history)``; pass back ``opt_state`` and
    ``start_epoch`` to continue (the evolution benchmarks train one epoch at
    a time to trace PSNR/OLR curves, paper Figs. 7/12/16).  ``on_epoch`` is
    an optional host callback ``(epoch, params, loss)`` invoked after every
    epoch (telemetry sample-PSNR hook); it forces a device sync per epoch,
    so leave it ``None`` on performance-sensitive paths.
    """
    epochs = cfg.epochs if epochs is None else epochs
    if opt_state is None:
        opt_state = adamw_init(params)
    n = inputs.shape[0]
    batch = min(cfg.batch, n)
    steps = max(1, n // batch)
    total_steps = steps * cfg.epochs
    xs = jnp.asarray(inputs)
    ys = jnp.asarray(targets)
    history = []
    key = jax.random.PRNGKey(cfg.seed)
    for e in range(start_epoch, start_epoch + epochs):
        ekey = jax.random.fold_in(key, e)
        start_step = jnp.asarray(e * steps, jnp.int32)
        params, opt_state, mloss = _train_epoch(
            params, opt_state, xs, ys, ekey, start_step,
            cfg_reg=net_cfg.regulated, cfg_skip=net_cfg.skip, batch=batch,
            steps=steps, total_steps=total_steps, base_lr=cfg.lr,
            min_lr_frac=cfg.min_lr_frac, loss=cfg.loss,
            lowering=cfg.lowering)
        history.append(float(mloss))
        if on_epoch is not None:
            on_epoch(e, params, history[-1])
    return params, opt_state, history


_predict = partial(jax.jit, static_argnames=("regulated", "skip", "batch",
                                             "lowering"))(predict_graph)


def predict_residual(params, inputs: np.ndarray,
                     net_cfg: skipping_dnn.SkippingDNNConfig,
                     batch: int = 64, lowering: str = "auto") -> np.ndarray:
    """Predicted normalized residual for every slice, [N,H,W]."""
    return np.asarray(_predict(params, jnp.asarray(inputs),
                               regulated=net_cfg.regulated,
                               skip=net_cfg.skip, batch=batch,
                               lowering=lowering))
