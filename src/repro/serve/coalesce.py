"""Request intake and batching window for the archive server.

Decode requests land on a thread-safe queue; the server's dispatcher
drains them in *batches*: the first request blocks until something
arrives, then the window stays open ``window_s`` seconds (or until
``max_batch`` requests) collecting whatever else lands.  Requests in one
batch that agree on the registry's ``decode_key`` signature — same
(compressor, shape, dtype, layout) — later execute as one stacked
``decompress_batched`` dispatch, so the window is what turns N concurrent
readers into one kernel launch.

The coalescer knows nothing about archives; it moves :class:`Request`
objects.  Each request carries a :class:`Future` the submitter blocks on.
"""
from __future__ import annotations

import itertools
import queue
import threading
import time

_STOP = object()        # sentinel: dispatcher should exit after this batch


class Future:
    """Minimal one-shot future (stdlib ``concurrent.futures.Future`` drags
    in executor semantics we don't want; this is set-once/wait)."""

    __slots__ = ("_event", "_value", "_error")

    def __init__(self):
        self._event = threading.Event()
        self._value = None
        self._error = None

    def set_result(self, value) -> None:
        self._value = value
        self._event.set()

    def set_error(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError("request still pending")
        if self._error is not None:
            raise self._error
        return self._value


class Request:
    """One pending decode: field ``name`` (optionally a ``roi``) against
    an archive registered under ``archive_id``."""

    __slots__ = ("archive_id", "name", "roi", "future", "seq")
    _seq = itertools.count()

    def __init__(self, archive_id: str, name: str, roi=None):
        self.archive_id = archive_id
        self.name = name
        self.roi = roi
        self.future = Future()
        self.seq = next(Request._seq)

    def __repr__(self) -> str:
        roi = f" roi={self.roi}" if self.roi is not None else ""
        return f"<Request #{self.seq} {self.archive_id}:{self.name}{roi}>"


class Coalescer:
    """Bounded request queue with a batching drain.

    ``window_s`` is the coalescing window: after the first request of a
    batch arrives, the drain keeps collecting until the window closes or
    ``max_batch`` requests are in hand.  ``window_s=0`` still coalesces
    whatever is *already* queued (one non-blocking sweep) — tests drive
    determinism by queueing first and draining second.
    """

    def __init__(self, *, window_s: float = 0.002, max_batch: int = 64):
        self.window_s = float(window_s)
        self.max_batch = int(max_batch)
        self._q: queue.Queue = queue.Queue()
        self._closed = False

    def submit(self, req: Request) -> Request:
        if self._closed:
            raise RuntimeError("coalescer is closed")
        self._q.put(req)
        return req

    def close(self) -> None:
        """Refuse new submits and wake the dispatcher for a final drain."""
        if not self._closed:
            self._closed = True
            self._q.put(_STOP)

    @property
    def closed(self) -> bool:
        return self._closed

    def pending(self) -> int:
        return self._q.qsize()

    def drain(self, *, block: bool = True) -> tuple[list[Request], bool]:
        """Collect one batch; returns ``(requests, stopping)``.

        Blocks for the first request (unless ``block=False``), then holds
        the window open for stragglers.  ``stopping=True`` means the stop
        sentinel was seen — serve what was returned, then exit.
        """
        batch: list[Request] = []
        try:
            first = self._q.get(block=block)
        except queue.Empty:
            return [], False
        if first is _STOP:
            return [], True
        batch.append(first)
        deadline = time.monotonic() + self.window_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            try:
                nxt = self._q.get(block=remaining > 0,
                                  timeout=max(remaining, 0) or None)
            except queue.Empty:
                break
            if nxt is _STOP:
                return batch, True
            batch.append(nxt)
        return batch, False
