"""Model configuration schema covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | hybrid | ssm | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    act: str = "silu"                # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    causal: bool = True              # False for encoder-only (hubert)

    # sliding-window / local:global interleave (gemma3)
    window_size: Optional[int] = None
    pattern_local: int = 0           # e.g. 5 local then 1 global per unit
    pattern_global: int = 0

    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_dense_layers: int = 0
    d_ff_dense: int = 0              # d_ff of the dense first layers
    moe_group_size: int = 2048       # GShard routing group (tokens)
    capacity_factor: float = 1.25

    # hybrid (zamba2): mamba2 blocks + one SHARED attention block every unit
    hybrid_attn_every: int = 0       # 0 = no hybrid; else unit = (k-1) mamba + 1 attn
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4

    # xLSTM: pattern of mLSTM with an sLSTM every unit
    xlstm_slstm_every: int = 0
    xlstm_proj_factor: float = 2.0

    # modality stubs
    input_kind: str = "tokens"       # tokens | embeddings (audio) | multimodal (vlm)
    frontend_tokens: int = 0         # vlm: image-patch positions per sample
    mask_ratio: float = 0.0          # audio: masked-prediction ratio

    # perf toggles (§Perf hillclimbing)
    attn_skip_uncausal: bool = False   # enumerate only causal chunk pairs
    sp_residual: bool = False          # sequence-parallel residual stream
                                       # (Korthikanti SP: AR -> AG+RS halves
                                       # TP collective traffic)
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def params_dtype(self):
        import jax.numpy as jnp
        return jnp.dtype(self.dtype)

    def n_params_estimate(self) -> int:
        """Rough dense-equivalent parameter count (reported in docs)."""
        d, l = self.d_model, self.n_layers
        attn = l * (d * self.hd * (self.n_heads + 2 * self.n_kv_heads) +
                    self.n_heads * self.hd * d)
        if self.moe:
            ff_per = 3 * d * self.d_ff_expert
            ff = l * (self.n_experts + self.n_shared_experts) * ff_per
        else:
            ff = l * 3 * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return attn + ff + emb

    def n_active_params(self) -> int:
        """Active (per-token) parameter count — MODEL_FLOPS uses this.

        For zamba2 the shared attention block executes once per unit (its
        weights are reused), and the remaining layers are Mamba2 blocks; for
        xLSTM the cells replace attention+FFN entirely."""
        d, l = self.d_model, self.n_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "hybrid":
            units = l // max(self.hybrid_attn_every, 1)
            attn_block = (d * self.hd * (self.n_heads + 2 * self.n_kv_heads)
                          + self.n_heads * self.hd * d + 3 * d * self.d_ff)
            di = self.ssm_expand * d
            nh = di // max(self.ssm_headdim, 1)
            mamba_block = (d * (2 * di + 2 * self.ssm_state + nh) + di * d)
            return units * attn_block + (l - units) * mamba_block + emb
        if self.family == "ssm":
            units = l // max(self.xlstm_slstm_every, 1)
            du = 2 * d
            mlstm = d * 2 * du + 3 * du * du + du * 2 * self.n_heads + du * d
            dff = int(self.xlstm_proj_factor * d)
            slstm = d * 4 * d + 3 * d * dff
            return (l - units) * mlstm + units * slstm + emb
        if not self.moe:
            return self.n_params_estimate()
        d, l = self.d_model, self.n_layers
        attn = l * (d * self.hd * (self.n_heads + 2 * self.n_kv_heads) +
                    self.n_heads * self.hd * d)
        ff = l * (self.top_k + self.n_shared_experts) * 3 * d * self.d_ff_expert
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return attn + ff + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
