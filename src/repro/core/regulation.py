"""Error regulation (§3.3): strict 1× control and relaxed 2× regulation.

* strict   — enhanced points whose error exceeds ``eb`` are outliers; their
  coordinates are stored (``repro.compressors.outliers``) and they are
  replaced by the decompressed value at decode time — which is in-bound by
  the conventional compressor's guarantee, so the 1× bound holds everywhere.
* relaxed  — no outlier storage; the regulated Sigmoid head already caps the
  added residual at ``±eb`` so the worst case is ``2×eb`` (Fig. 6 Case B).
* unregulated — linear head, no guarantee (paper ablation; better PSNR,
  worse MAE/DSSIM tails).
"""
from __future__ import annotations

import numpy as np

MODES = ("strict", "relaxed", "unregulated")


def enhance(decomp: np.ndarray, resid_norm: np.ndarray, eb: float,
            out_dtype=None) -> np.ndarray:
    """X̂ = X' + R̂ where R̂ = resid_norm * eb (resid_norm from the DNN)."""
    out_dtype = out_dtype or decomp.dtype
    enh = decomp.astype(np.float64) + resid_norm.astype(np.float64) * eb
    return enh.astype(out_dtype)


def outlier_mask(orig: np.ndarray, enhanced: np.ndarray, eb: float) -> np.ndarray:
    """Points where the *final-dtype* enhanced value violates the 1× bound."""
    err = np.abs(enhanced.astype(np.float64) - orig.astype(np.float64))
    return err > eb


def apply_strict(enhanced: np.ndarray, decomp: np.ndarray,
                 mask: np.ndarray) -> np.ndarray:
    """Replace outliers with the in-bound decompressed values (Fig. 5)."""
    out = enhanced.copy()
    out[mask] = decomp[mask]
    return out


def check_bound(orig: np.ndarray, rec: np.ndarray, eb: float, mode: str) -> dict:
    """Verification helper used by tests/benchmarks (paper 'error validation')."""
    err = np.abs(rec.astype(np.float64) - orig.astype(np.float64))
    finite = np.isfinite(np.asarray(orig, dtype=np.float64))
    maxerr = float(err[finite].max()) if finite.any() else 0.0
    limit = {"strict": eb, "relaxed": 2.0 * eb, "unregulated": np.inf}[mode]
    return {
        "max_abs_err": maxerr,
        "bound": limit,
        "ok": bool(maxerr <= limit),
        "olr": float((err[finite] > eb).mean()) if finite.any() else 0.0,
    }
