"""hubert-xlarge [audio]: 48L d_model=1280 16H d_ff=5120 vocab=504 —
encoder-only (bidirectional), masked-prediction objective; the conv waveform
frontend is a STUB (input_specs provides precomputed frame embeddings)
[arXiv:2106.07447; unverified]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120, vocab_size=504,
    act="gelu", causal=False, input_kind="embeddings", mask_ratio=0.08,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=128,
                               vocab_size=64, dtype="float32")
