"""Roofline report: reads experiments/dryrun/*.json into the
(arch x shape x mesh) table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

from . import common


def load_records(out_dir="experiments/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(full: bool = False):
    recs = load_records()
    if not recs:
        common.csv_row("roofline/none", 0.0, "no dryrun records found")
        return
    for r in recs:
        if r.get("status") != "ok":
            common.csv_row(f"roofline/{r['arch']}/{r.get('shape')}", 0.0,
                           f"status=FAIL;err={r.get('error', '?')[:60]}")
            continue
        t = r["roofline"]
        mesh = "x".join(str(v) for v in r["mesh"].values())
        ucr = r.get("useful_compute_ratio")
        common.csv_row(
            f"roofline/{r['arch']}/{r['shape']}/mesh{mesh}", 0.0,
            f"dominant={t['dominant']};compute_ms={t['compute_s']*1e3:.2f};"
            f"memory_ms={t['memory_s']*1e3:.2f};"
            f"collective_ms={t['collective_s']*1e3:.2f};"
            f"peak_hbm_gib={r['memory']['peak_hbm_bytes']/2**30:.2f};"
            f"useful_compute_ratio={ucr if ucr is None else round(ucr, 3)}")


if __name__ == "__main__":
    run()
