"""Framework feature: NeurLZ-style compression applied to gradients and
checkpoints (the paper's technique in the trainer, DESIGN.md §4)."""
from __future__ import annotations

import time

import jax
import numpy as np

from . import common
from repro import configs
from repro.models import model as M
from repro.optim import grad_compress as GC


def run(full: bool = False):
    cfg = configs.get_reduced("qwen3-4b")
    model = M.build_model(cfg, model_axis=1)
    params, opt = M.init_train_state(model)
    batch = M.demo_batch(cfg, batch=4, seq=64)

    def loss_fn(p):
        return model.loss(p, batch)

    grads = jax.grad(loss_fn)(params)

    # int8 error-feedback quantization: wire-byte ratio + error
    t0 = time.time()
    ef = GC.init_ef(grads)
    q, s, ef2 = GC.quantize_ef(grads, ef, bits=8)
    deq = GC.dequantize(q, s)
    g_flat = np.concatenate([np.asarray(g, np.float32).ravel()
                             for g in jax.tree.leaves(grads)])
    d_flat = np.concatenate([np.asarray(g, np.float32).ravel()
                             for g in jax.tree.leaves(deq)])
    rel_rmse = float(np.sqrt(np.mean((g_flat - d_flat) ** 2))
                     / (np.sqrt(np.mean(g_flat ** 2)) + 1e-30))
    common.csv_row("gradcomp/int8_ef", (time.time() - t0) * 1e6,
                   f"wire_ratio=4.0;rel_rmse={rel_rmse:.4f}")

    # NeurLZ error-bounded archive of the gradient tree
    t0 = time.time()
    rep = GC.neurlz_grad_archive(grads, rel_eb=1e-3)
    common.csv_row("gradcomp/neurlz_eb1e-3", (time.time() - t0) * 1e6,
                   f"ratio={rep['ratio']:.2f};raw_mb={rep['raw_bytes']/2**20:.2f}")

    # lossy checkpoint compression ratio
    from repro.checkpoint.checkpoint import _flatten, _pack_arrays
    t0 = time.time()
    flat = _flatten(params)
    raw = sum(a.nbytes for a in flat.values())
    lossless = len(_pack_arrays(flat))
    lossy = len(_pack_arrays(flat, lossy_eb=1e-4))
    common.csv_row("ckptcomp/weights", (time.time() - t0) * 1e6,
                   f"raw_mb={raw/2**20:.2f};lossless_ratio={raw/lossless:.2f};"
                   f"neurlz_eb1e-4_ratio={raw/lossy:.2f}")


if __name__ == "__main__":
    run()
