"""NeurLZ — the paper's contribution, end to end (§3.1, Fig. 3).

Compression:
  1. conventional error-bounded compression of every field (SZ3-like or
     ZFP-like), keeping the encoder-side reconstruction,
  2. per-field *online* training of a skipping-DNN enhancer on the residual
     ``X − X'`` (cross-field channels optional),
  3. error regulation: strict (store outlier coordinates) or relaxed
     (regulated 2× bound, nothing stored) or unregulated (ablation),
  4. package conventional payload + DNN weights + outliers into one archive.

Decompression mirrors it: conventional decode → enhancer inference →
``X̂ = X' + R̂`` → outlier patch.  All decoder inputs (normalization stats,
weights) come from the archive, and the conventional reconstruction is
bit-identical on both sides, so decode reproduces the encoder's enhanced
field exactly.

Three compression engines share this module's helpers:
  * ``engine="serial"``   — one field at a time, one dispatch per epoch per
    field; the reference implementation.
  * ``engine="batched"``  — the multi-field engine
    (:mod:`repro.core.batched_engine`): all fields of a snapshot train in a
    single dispatch per epoch, CPU-side conventional compression overlaps
    device-side training, and the stacked field axis can be sharded across
    devices.  Archives are bit-identical to the serial engine under the
    default ``field_batching="auto"`` strategy (stacked ``vmap`` for
    uniform groups, per-field unroll for ragged ones).
  * ``engine="streaming"`` — the bounded-memory pipeline
    (:mod:`repro.streaming`): fields are pulled lazily from a chunked
    source, conventional reconstructions are refcounted and evicted the
    moment their last cross-field consumer finishes, and entry packing +
    archival run on a writer thread under a hard ``max_resident_bytes``
    budget.  Entries are bit-identical to the serial engine's.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Mapping

import jax
import numpy as np

from .. import compressors
from .. import faults as faults_lib
from ..compressors import outliers as outlier_codec
from ..obs import telemetry as obs_lib
from . import archive as arc_io
from . import bounds as bounds_lib
from . import conv_stage as conv_stage_lib
from . import metrics, online_trainer, regulation, skipping_dnn


@dataclasses.dataclass(frozen=True)
class NeurLZConfig:
    compressor: str = "szlike"          # szlike | szlike-lorenzo | zfplike
    mode: str = "strict"                # strict | relaxed | unregulated
    epochs: int = 100
    batch: int = 10
    lr: float = 1e-2
    seed: int = 0
    slice_axis: int = 0
    skip: bool = True                   # skipping vs plain DNN (ablation)
    learn_residual: bool = True         # residual vs direct learning (ablation)
    cross_field: Mapping[str, tuple] = dataclasses.field(default_factory=dict)
    weight_dtype: str = "float32"       # archive precision for DNN weights
    widths: tuple = (4, 4, 6, 6, 8)
    engine: str = "serial"              # serial | batched | streaming
    conv_batch: bool = True             # snapshot-batched conventional stage
    field_batching: str = "auto"        # auto | unroll | vmap (stacked)
    lowering: str = "auto"              # eager | jit | pallas | auto — kernel
    #   lowering for the hot ops (repro.kernels.dispatch); every choice is
    #   byte-identical to eager or falls back, so archives never depend on it
    group_size: int = 2                 # fields per batched dispatch (0 = all)
    prefetch: bool = True               # overlap CPU conv stage with training
    field_shard: bool = True            # spread field groups over devices
    max_resident_bytes: int = 0         # streaming residency budget (0 = off)
    telemetry: object | None = None     # repro.obs.Telemetry handle (None =
    #   disabled: every instrumentation point is a shared no-op singleton)
    faults: object | None = None        # repro.faults.FaultConfig (None =
    #   defaults: no injection, no retries, conv-only degradation on)

    def net_config(self, c_in: int) -> skipping_dnn.SkippingDNNConfig:
        return skipping_dnn.SkippingDNNConfig(
            c_in=c_in, widths=self.widths,
            regulated=(self.mode != "unregulated"), skip=self.skip)

    def train_config(self) -> online_trainer.TrainConfig:
        return online_trainer.TrainConfig(
            epochs=self.epochs, batch=self.batch, lr=self.lr, seed=self.seed,
            slice_axis=self.slice_axis, lowering=self.lowering)


def _aux_names(cfg: NeurLZConfig, name: str, fields) -> list[str]:
    aux = list(cfg.cross_field.get(name, ()))
    missing = [a for a in aux if a not in fields]
    if missing:
        raise KeyError(f"cross-field aux {missing} not in input fields")
    return aux


def field_config(config: NeurLZConfig, mode: str | None) -> NeurLZConfig:
    """The effective config for one field under a per-field regulation mode
    (``None`` or the session mode -> the session config unchanged, which is
    what keeps legacy single-bound runs on the exact historical path)."""
    if mode is None or mode == config.mode:
        return config
    return dataclasses.replace(config, mode=mode)


_warned_shims: set[str] = set()


def _warn_legacy(fn: str, repl: str) -> None:
    """One ``DeprecationWarning`` per process per legacy dict-API shim."""
    if fn in _warned_shims:
        return
    _warned_shims.add(fn)
    warnings.warn(
        f"repro.core.{fn}() is a legacy dict-API shim; prefer {repl} "
        "(see the README migration table)", DeprecationWarning, stacklevel=3)


# ---------------------------------------------------------------------------
# Helpers shared by both engines.  The batched engine builds entries through
# the very same functions, which is what keeps archives bit-compatible.
# ---------------------------------------------------------------------------

def build_dataset(x: np.ndarray, rec: np.ndarray, eb: float,
                  aux: list[np.ndarray], config: NeurLZConfig):
    """Per-field training tensors honoring the residual/direct ablation."""
    inputs, targets, stats = online_trainer.make_dataset(
        rec, x, eb, aux=aux, slice_axis=config.slice_axis)
    if not config.learn_residual:
        # Ablation: learn the normalized original directly (paper Fig. 4
        # "non-residual"), scaled by the decomp std so magnitudes match.
        mu, sd = stats[0]
        o = np.moveaxis(np.asarray(x, np.float64), config.slice_axis, 0)
        targets = (((o - mu) / sd).astype(np.float32))[..., None]
    return inputs, targets, stats


def pack_entry(config: NeurLZConfig, conv_arc: dict, params, stats,
               aux: list[str], eb: float, net_cfg, history,
               collect_stats: bool) -> dict:
    return {
        "conv": conv_arc,
        "weights": arc_io.pack_weights(params, config.weight_dtype),
        "stats": [list(s) for s in stats],
        "aux": aux,
        "mode": config.mode,
        "abs_eb": eb,
        "net": {"c_in": net_cfg.c_in, "widths": list(config.widths),
                "regulated": net_cfg.regulated, "skip": net_cfg.skip},
        "learn_residual": config.learn_residual,
        "loss_history": history if collect_stats else [],
    }


def pack_degraded_entry(config: NeurLZConfig, conv_arc: dict, eb: float,
                        reason: str) -> dict:
    """Conv-only entry for a field whose enhancer failed (non-finite loss,
    injected fault, OOM).  No weights/net — decode returns the conventional
    reconstruction, which already honors the exact ``abs_eb`` (the
    conventional stage guarantees ``|x - x'| <= eb``, tighter than both the
    strict 1x and relaxed 2x contracts).  ``reason`` is the normalized
    :func:`repro.faults.degrade_reason` string, so every engine emits a
    byte-identical entry for the same failure."""
    return {
        "conv": conv_arc,
        "stats": [],
        "aux": [],
        "mode": config.mode,
        "abs_eb": eb,
        "learn_residual": config.learn_residual,
        "loss_history": [],
        "degraded": reason,
    }


def history_is_finite(history) -> bool:
    """False when the training-loss trajectory went NaN/inf — the enhancer
    weights are poisoned from that epoch on, so the field degrades."""
    if not history:
        return True
    return bool(np.all(np.isfinite(np.asarray(history, dtype=np.float64))))


def enhance_and_mask(x: np.ndarray, rec: np.ndarray, resid_norm: np.ndarray,
                     eb: float, stats, config: NeurLZConfig):
    """Encoder-side enhancement; returns ``(field_rec, mask)`` where ``mask``
    is the strict-mode outlier mask (``None`` otherwise).  Split from
    :func:`finalize_entry` so the streaming pipeline can capture the mask on
    the compute thread and defer its *encoding* to the writer thread."""
    resid_norm = np.moveaxis(resid_norm, 0, config.slice_axis)
    if config.learn_residual:
        # Hot path: fused enhance + regulate + outlier capture through the
        # kernel-lowering dispatcher (byte-identical to the sequence below
        # by the dispatch parity contract).
        return regulation.enhance_lowered(
            rec, resid_norm, x, eb, out_dtype=x.dtype, mode=config.mode,
            lowering=config.lowering)
    field_rec = _apply_enhancement(rec, resid_norm, eb, x.dtype, stats, config)
    mask = None
    if config.mode == "strict":
        mask = regulation.outlier_mask(x, field_rec, eb)
        field_rec = regulation.apply_strict(field_rec, rec, mask)
    return field_rec, mask


def finalize_entry(entry: dict, x: np.ndarray, rec: np.ndarray,
                   resid_norm: np.ndarray, eb: float, stats,
                   config: NeurLZConfig) -> np.ndarray:
    """Enhancement + strict-mode outlier capture; mutates ``entry``."""
    field_rec, mask = enhance_and_mask(x, rec, resid_norm, eb, stats, config)
    if mask is not None:
        entry["outliers"] = outlier_codec.encode_outliers(mask)
    return field_rec


def assemble_archive(fields: Mapping[str, np.ndarray], out_fields: dict,
                     config: NeurLZConfig, timing: dict) -> dict:
    # Entries land in input-field order regardless of engine scheduling.
    arc = {
        "kind": "neurlz",
        "fields": {name: out_fields[name] for name in fields},
        "slice_axis": config.slice_axis,
        "compressor": config.compressor,
        "timing": timing,
    }
    arc["bitrate"] = {n: field_bitrate(arc, n, int(np.asarray(fields[n]).size))
                      for n in fields}
    return arc


def compress(fields: Mapping[str, np.ndarray], rel_eb: float | None = None, *,
             abs_eb: float | None = None, config: NeurLZConfig = NeurLZConfig(),
             collect_stats: bool = True, bounds=None) -> dict:
    """Compress a dict of fields of one snapshot into a NeurLZ archive dict.

    Legacy dict-API shim — :class:`repro.NeurLZ` / :class:`repro.Archive`
    are the first-class surface.  ``bounds`` optionally carries per-field
    :class:`repro.core.bounds.ErrorBound` specs (see
    :func:`repro.core.bounds.resolve_bounds` for the accepted forms).
    """
    _warn_legacy("compress", "repro.NeurLZ(...).compress(...)")
    return compress_impl(fields, rel_eb, abs_eb=abs_eb, config=config,
                         collect_stats=collect_stats, bounds=bounds)


def compress_impl(fields, rel_eb=None, *, abs_eb=None,
                  config: NeurLZConfig = NeurLZConfig(),
                  collect_stats: bool = True, bounds=None) -> dict:
    """Engine dispatch shared by the dict shim and the session API."""
    if config.engine == "batched":
        from . import batched_engine
        return batched_engine.compress(fields, rel_eb, abs_eb=abs_eb,
                                       config=config,
                                       collect_stats=collect_stats,
                                       bounds=bounds)
    if config.engine == "streaming":
        from ..streaming import pipeline
        return pipeline.compress_dict(fields, rel_eb, abs_eb=abs_eb,
                                      config=config,
                                      collect_stats=collect_stats,
                                      bounds=bounds)
    if config.engine != "serial":
        raise ValueError(f"unknown engine {config.engine!r} "
                         "(want 'serial', 'batched' or 'streaming')")
    return _compress_serial(fields, rel_eb, abs_eb=abs_eb, config=config,
                            collect_stats=collect_stats, bounds=bounds)


def field_vrange(x: np.ndarray) -> float:
    """Finite value range of a field (0.0 when nothing is finite) — the
    reference the learning-trace PSNR predictions are computed against."""
    v = np.asarray(x, dtype=np.float64)
    v = v[np.isfinite(v)]
    if v.size == 0:
        return 0.0
    return float(v.max() - v.min())


def entry_base_bytes(entry: dict) -> float:
    """Conv payload + enhancer weight bytes of a packed entry — the
    epoch-independent part of the learning-trace bitrate prediction."""
    return (compressors.archive_nbytes(entry["conv"])
            + (entry["weights"]["nbytes"] if "weights" in entry else 0))


def _sample_psnr_hook(tel, x, rec, inputs, eb, stats, config, net_cfg):
    """Per-epoch measured-PSNR hook for the serial trainer (telemetry
    ``sample_psnr`` mode): predicts the residual on a few sampled slices
    after every epoch and scores the pre-regulation enhancement against the
    original.  Returns ``(on_epoch, samples)`` — ``(None, None)`` when
    disabled (the fused engines have no per-epoch host hook)."""
    if not (tel.enabled and tel.config.sample_psnr):
        return None, None
    n = inputs.shape[0]
    k = max(1, min(int(tel.config.sample_slices), n))
    idx = np.linspace(0, n - 1, k).astype(int)
    x_s = np.moveaxis(np.asarray(x), config.slice_axis, 0)[idx]
    rec_s = np.moveaxis(np.asarray(rec), config.slice_axis, 0)[idx]
    inp_s = np.ascontiguousarray(inputs[idx])
    samples: list[float] = []

    def on_epoch(epoch, params, loss):
        resid = online_trainer.predict_residual(params, inp_s, net_cfg,
                                                lowering=config.lowering)
        enh = _apply_enhancement(rec_s, resid, eb, x_s.dtype, stats, config)
        samples.append(metrics.psnr(x_s, enh))

    return on_epoch, samples


def _compress_serial(fields, rel_eb, *, abs_eb, config, collect_stats,
                     bounds=None):
    tel = obs_lib.of(config)
    fc = faults_lib.of(config)
    t0 = time.time()
    with tel.span("compress", root=True, engine="serial",
                  fields=len(fields)):
        # Per-field error-bound specs (None -> legacy single-scalar path).
        resolved = None
        if bounds is not None:
            resolved = bounds_lib.resolve_bounds(list(fields), bounds,
                                                 rel_eb, abs_eb,
                                                 default_mode=config.mode)
        # Shared conventional stage: the whole snapshot is one plan, so
        # fields sharing a (shape, dtype, bound spec) compress through the
        # fused entry.
        stage = conv_stage_lib.ConvStage(config.compressor, rel_eb, abs_eb,
                                         batch=config.conv_batch,
                                         bounds=resolved, telemetry=tel,
                                         lowering=config.lowering)
        conv = stage.run(fields)
        conv_arcs = {n: arc for n, (arc, _) in conv.items()}
        recs = {n: rec for n, (_, rec) in conv.items()}
        ebs = {n: arc["abs_eb"] for n, arc in conv_arcs.items()}

        # A reconstruction stays resident only until its last consumer (its
        # own finalize + every field listing it as cross-field aux) is done
        # — the streaming pipeline's refcount idea in miniature.
        rec_refs = {n: 1 for n in fields}
        for n in fields:
            for a in _aux_names(config, n, fields):
                rec_refs[a] += 1

        out_fields = {}
        degraded: list[str] = []
        train_time = 0.0
        for name, x in fields.items():
            x = np.asarray(x)
            eb = ebs[name]
            fcfg = field_config(config,
                                resolved[name].mode if resolved else None)
            aux_names = _aux_names(fcfg, name, fields)
            aux = [recs[a] for a in aux_names]
            net_cfg = fcfg.net_config(1 + len(aux))
            tcfg = fcfg.train_config()

            entry, sampled, reason = None, None, None
            with tel.span("train", field=name):
                try:
                    fc.check(f"train.{name}")
                    inputs, targets, stats = build_dataset(x, recs[name], eb,
                                                           aux, fcfg)

                    key = jax.random.PRNGKey(tcfg.seed)
                    params = skipping_dnn.init_params(key, net_cfg)
                    on_epoch, sampled = _sample_psnr_hook(
                        tel, x, recs[name], inputs, eb, stats, fcfg, net_cfg)
                    tt = time.time()
                    params, _, history = online_trainer.train(
                        params, inputs, targets, tcfg, net_cfg,
                        on_epoch=on_epoch)
                    train_time += time.time() - tt

                    if fc.degrade and not history_is_finite(history):
                        reason = faults_lib.degrade_reason()
                    else:
                        resid_norm = online_trainer.predict_residual(
                            params, inputs, net_cfg,
                            lowering=fcfg.lowering)
                        entry = pack_entry(fcfg, conv_arcs[name], params,
                                           stats, aux_names, eb, net_cfg,
                                           history, collect_stats)
                        finalize_entry(entry, x, recs[name], resid_norm, eb,
                                       stats, fcfg)
                except Exception as exc:
                    if not (fc.degrade and faults_lib.is_degradable(exc)):
                        raise
                    reason = faults_lib.degrade_reason(exc)
            if reason is not None:
                entry = pack_degraded_entry(fcfg, conv_arcs[name], eb, reason)
                degraded.append(name)
                tel.counter("faults.degraded").add()
            elif tel.enabled and tel.config.learning_traces:
                obs_lib.learning_trace(
                    tel, name, history, eb=eb, vrange=field_vrange(x),
                    base_bytes=entry_base_bytes(entry), n_points=int(x.size),
                    mode=fcfg.mode, sample_psnr=sampled)
            out_fields[name] = entry
            for m in (name, *aux_names):
                rec_refs[m] -= 1
                if rec_refs[m] <= 0:
                    recs.pop(m, None)

        timing = obs_lib.build_timing(
            tel, total_s=time.time() - t0, conv_s=stage.stats.conv_s,
            train_s=train_time, conv_stage=stage.stats.as_dict(),
            degraded_fields=degraded)
        with tel.span("assemble"):
            return assemble_archive(fields, out_fields, config, timing)


def _apply_enhancement(rec, resid_norm, eb, out_dtype, stats, config) -> np.ndarray:
    if config.learn_residual:
        return regulation.enhance(rec, resid_norm, eb, out_dtype)
    # Direct-learning ablation: the net predicts the normalized value itself.
    mu, sd = stats[0]
    return (resid_norm.astype(np.float64) * sd + mu).astype(out_dtype)


def decode_entry_net(entry: dict):
    """Rebuild (net_cfg, params) for one archived field entry."""
    net = entry["net"]
    net_cfg = skipping_dnn.SkippingDNNConfig(
        c_in=net["c_in"], widths=tuple(net["widths"]),
        regulated=net["regulated"], skip=net["skip"])
    params = skipping_dnn.init_params(jax.random.PRNGKey(0), net_cfg)
    params = arc_io.unpack_weights(entry["weights"], params)
    return net_cfg, params


def apply_decoded_entry(entry: dict, rec: np.ndarray, resid_norm: np.ndarray,
                        slice_axis: int) -> np.ndarray:
    """Decode-side enhancement + outlier patch from archived metadata."""
    eb = entry["abs_eb"]
    resid_norm = np.moveaxis(resid_norm, 0, slice_axis)
    stats = [tuple(s) for s in entry["stats"]]
    dtype = np.dtype(entry["conv"]["dtype"])
    cfg = NeurLZConfig(mode=entry["mode"],
                       learn_residual=entry["learn_residual"])
    out = _apply_enhancement(rec, resid_norm, eb, dtype, stats, cfg)
    if entry["mode"] == "strict" and "outliers" in entry:
        mask = outlier_codec.decode_outliers(entry["outliers"])
        out = regulation.apply_strict(out, rec, mask)
    return out


def decode_field_entry(e: dict, rec: np.ndarray, aux: list,
                       slice_axis: int) -> np.ndarray:
    """Full single-field decode from its archive entry + conventional
    reconstructions (its own and its aux fields'): enhancer inference +
    enhancement + outlier patching.  The one decode body shared by the
    serial path, streaming ``iter_decompress`` and ``Archive.decode``."""
    if e.get("degraded"):
        # Conv-only entry (enhancer failure at compress time): the
        # conventional reconstruction IS the decode, bound already honored.
        return np.asarray(rec)
    net_cfg, params = decode_entry_net(e)
    stats = [tuple(s) for s in e["stats"]]
    inputs, _, _ = online_trainer.make_dataset(
        rec, None, e["abs_eb"], aux=aux, slice_axis=slice_axis, stats=stats)
    resid_norm = online_trainer.predict_residual(params, inputs, net_cfg)
    return apply_decoded_entry(e, rec, resid_norm, slice_axis)


def decompress(arc, *, engine: str = "serial") -> dict[str, np.ndarray]:
    """Full decode: conventional + enhancer inference + outlier patching.

    Legacy dict-API shim over :func:`decompress_impl` (prefer
    ``Archive.decode`` / ``Archive.decode_all``).  ``engine="batched"``
    runs every field's enhancer inference in a single dispatch
    (bit-identical output — the batched path inlines the exact serial
    inference graph per field).  Accepts archive dicts and
    :class:`repro.core.archive_api.Archive` handles alike.
    """
    _warn_legacy("decompress", "Archive.decode_all(...) / Archive.decode(...)")
    return decompress_impl(arc, engine=engine)


def decompress_impl(arc, *, engine: str = "serial") -> dict[str, np.ndarray]:
    if engine == "batched":
        from . import batched_engine
        return batched_engine.decompress(arc)
    slice_axis = arc["slice_axis"]
    recs = {name: compressors.decompress(e["conv"])
            for name, e in arc["fields"].items()}
    out = {}
    for name, e in arc["fields"].items():
        aux = [recs[a] for a in e["aux"]]
        out[name] = decode_field_entry(e, recs[name], aux, slice_axis)
    return out


def field_bitrate(arc: dict, name: str, num_points: int) -> dict:
    """Paper bit-rate accounting: size(Z) + supplementary, bits/value."""
    e = arc["fields"][name]
    conv_b = compressors.archive_nbytes(e["conv"])
    weight_b = e["weights"]["nbytes"] if "weights" in e else 0.0
    out_b = 0.0
    out_bits_paper = 0.0
    if "outliers" in e:
        out_b = e["outliers"]["nbytes"]
        out_bits_paper = e["outliers"]["packed_bits"]
    total = conv_b + weight_b + out_b
    return {
        "conv_bytes": conv_b,
        "weight_bytes": weight_b,
        "outlier_bytes": out_b,
        "outlier_bits_paper_formula": out_bits_paper,
        "total_bytes": total,
        "bitrate": metrics.bitrate(total, num_points),
        "conv_bitrate": metrics.bitrate(conv_b, num_points),
    }


def save(path: str, arc: dict) -> int:
    """Write a whole-dict archive file.  Legacy dict-API shim: an
    :class:`Archive` handle is materialized first, preserving the historical
    ``save(load(streaming_path))`` round-trip, which converted a streaming
    container into the whole-dict format.  (``Archive.save`` instead keeps
    the native container and copies bytes.)"""
    _warn_legacy("save", "Archive.save(path)")
    from . import archive_api
    if isinstance(arc, archive_api.Archive):
        arc = arc.to_dict()
    return arc_io.save(path, arc)


def assemble_streaming_archive(reader: arc_io.ArchiveReader) -> dict:
    """Reassemble a streaming container into the whole-dict archive format.

    Entries land in the snapshot's input-field order (recorded in the index
    footer), so the result is byte-compatible with what the in-memory
    engines produce.
    """
    meta = reader.meta
    fields = {name: reader.read_entry(name) for name in meta["field_order"]}
    arc = {
        "kind": "neurlz",
        "fields": fields,
        "slice_axis": meta["slice_axis"],
        "compressor": meta["compressor"],
        "timing": meta.get("timing", {}),
    }
    arc["bitrate"] = {
        n: field_bitrate(arc, n, int(np.prod(meta["shapes"][n])))
        for n in fields}
    return arc


def load(path: str):
    """Open an archive file (either container format).

    Legacy dict-API shim.  A whole-dict file loads into the plain archive
    dict exactly as before.  A streaming (``NLZSTRM1``) container now comes
    back as a **lazy** :class:`repro.core.archive_api.Archive` handle —
    dict-compatible for reads (``arc["fields"]`` etc. materialize on first
    access) but O(1) in resident bytes at open time, fixing the regression
    where opening an out-of-core archive reassembled every field in
    memory.  Two contract deltas for that case: the handle is a *read-only*
    mapping (mutate ``arc.to_dict()`` instead), and it holds the container
    file open — call ``arc.close()`` (or use it as a context manager) when
    done; dropping the last reference also closes it.
    """
    _warn_legacy("load", "repro.Archive.open(path)")
    if arc_io.is_streaming_archive(path):
        from . import archive_api
        return archive_api.Archive.open(path)
    return arc_io.load(path)
