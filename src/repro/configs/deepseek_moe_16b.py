"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA kv=16) d_ff_expert=1408
vocab=102400, 64 routed top-6 + 2 shared experts, first layer dense
(d_ff=10944) — fine-grained expert segmentation  [arXiv:2401.06066; hf]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=102400,
    act="silu", rope_theta=1e4,
    moe=True, n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
    first_dense_layers=1, d_ff_dense=10944,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=3, d_model=64, n_heads=4,
                               n_kv_heads=4, head_dim=16, d_ff=64,
                               vocab_size=256, n_experts=8, top_k=2,
                               n_shared_experts=1, d_ff_expert=32,
                               first_dense_layers=1, d_ff_dense=128,
                               moe_group_size=64, dtype="float32")
