"""Pallas TPU kernels for the compression hot spots (+ ops.py wrappers,
ref.py pure-jnp oracles).  Validated in interpret mode on CPU; written
against the TPU memory hierarchy (HBM -> VMEM tiles, VPU elementwise)."""
from . import ops, ref  # noqa: F401
