"""Regenerate the EXPERIMENTS.md dry-run/roofline tables from the JSON
records in experiments/dryrun (run after a dry-run sweep)."""
import glob
import json
import sys


def fmt_table(out_dir="experiments/dryrun"):
    recs = [json.load(open(p)) for p in sorted(glob.glob(f"{out_dir}/*.json"))]
    ok = [r for r in recs if r.get("status") == "ok"]
    lines = []
    lines.append(f"{len(ok)}/{len(recs)} cells compiled OK.\n")
    lines.append("| arch | shape | mesh | dominant | compute ms | memory ms | "
                 "collective ms | peak HBM GiB | useful ratio |")
    lines.append("|---|---|---|---|---:|---:|---:|---:|---:|")
    for r in sorted(ok, key=lambda r: (r["arch"], str(r.get("shape")),
                                       len(r["mesh"]))):
        t = r["roofline"]
        mesh = "2x16x16" if "pod" in r["mesh"] else "16x16"
        u = r.get("useful_compute_ratio")
        lines.append(
            f"| {r['arch']} | {r.get('shape','-')} | {mesh} | {t['dominant']} "
            f"| {t['compute_s']*1e3:.1f} | {t['memory_s']*1e3:.1f} "
            f"| {t['collective_s']*1e3:.1f} "
            f"| {r['memory']['peak_hbm_bytes']/2**30:.2f} "
            f"| {('%.3f' % u) if u is not None else '—'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(fmt_table(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"))
