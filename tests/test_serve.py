"""The serving tier: coalesced concurrent decode, the ledger-charged
hot-field cache, transcode byte-parity and resume, and fault isolation.

Coalescing assertions go through :class:`registry.DecodeStats` — the
dispatch counters are the contract's observable: N concurrent
same-signature requests must execute as **one** stacked
``decompress_batched`` dispatch.  Determinism comes from
``auto_start=False``: requests queue first, the dispatcher starts after,
so one batch holds them all regardless of scheduler timing.
"""
import os
import threading

import numpy as np
import pytest

import repro
from repro import core, obs, streaming
from repro.core import archive as arc_io
from repro.core.archive_api import Archive
from repro.data import fields as F
from repro.faults import FaultConfig, FaultInjector, InjectedFault
from repro.serve import ArchiveServer, HotFieldCache, transcode
from repro.streaming.pipeline import ResidencyLedger

FIELDS = F.make_fields("nyx", shape=(8, 16, 16), seed=11)
NAMES = list(FIELDS)
CROSS = {NAMES[0]: (NAMES[1],)}
FIELD_NBYTES = FIELDS[NAMES[0]].nbytes


def _cfg(engine="streaming", **kw):
    return core.NeurLZConfig(epochs=2, mode="strict", engine=engine, **kw)


@pytest.fixture(scope="module")
def snap_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serve") / "snap.nlzs")
    streaming.compress(FIELDS, path, rel_eb=1e-3,
                       config=_cfg(cross_field=CROSS))
    return path


@pytest.fixture(scope="module")
def reference(snap_path):
    with Archive.open(snap_path) as arc:
        return {n: arc.decode(n) for n in NAMES}


# ---------------------------------------------------------------------------
# Coalescing: N concurrent requests -> one stacked dispatch
# ---------------------------------------------------------------------------

def test_concurrent_requests_coalesce_to_one_dispatch(snap_path, reference):
    srv = ArchiveServer(snap_path, max_bytes=1 << 30, auto_start=False)
    futs = {}
    barrier = threading.Barrier(len(NAMES))

    def client(name):
        barrier.wait()
        futs[name] = srv.submit(name)

    threads = [threading.Thread(target=client, args=(n,)) for n in NAMES]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    srv.start()
    out = {n: futs[n].result(30) for n in NAMES}
    srv.close()
    # bit-identical to direct Archive.decode, per field
    for n in NAMES:
        assert np.array_equal(out[n], reference[n]), n
    # the whole batch (4 same-signature fields; the aux producer is one of
    # them, so its conv dedups) ran as ONE stacked dispatch
    st = srv.decode_stats
    assert st.batched == 1 and st.single == 0, st.as_dict()
    assert st.max_width == len(NAMES)
    assert st.archives == len(NAMES)


def test_duplicate_requests_share_one_decode(snap_path, reference):
    srv = ArchiveServer(snap_path, max_bytes=1 << 30, auto_start=False)
    futs = [srv.submit(NAMES[3]) for _ in range(5)]
    srv.start()
    outs = [f.result(30) for f in futs]
    srv.close()
    for o in outs:
        assert np.array_equal(o, reference[NAMES[3]])
    # five requests, one field, one decode dispatch
    assert srv.decode_stats.dispatches == 1
    assert srv.decode_stats.archives == 1


def test_blocking_decode_and_stats_surface(snap_path, reference):
    with ArchiveServer(snap_path, max_bytes=1 << 30) as srv:
        out = srv.decode(NAMES[2])
        assert np.array_equal(out, reference[NAMES[2]])
        st = srv.stats()
        assert st["requests"] == 1
        assert st["decode"]["archives"] >= 1
        assert st["max_bytes"] == 1 << 30


def test_copy_results_isolation(snap_path, reference):
    """Default serving hands each caller its own buffer: mutating one
    tenant's result must not corrupt the cache other tenants read."""
    with ArchiveServer(snap_path, max_bytes=1 << 30) as srv:
        a = srv.decode(NAMES[3])
        a[:] = -1.0
        b = srv.decode(NAMES[3])
        assert np.array_equal(b, reference[NAMES[3]])


# ---------------------------------------------------------------------------
# Cache: hits skip disk, eviction respects the shared ledger ceiling
# ---------------------------------------------------------------------------

def test_cache_hit_skips_entry_reads(snap_path):
    tel = obs.Telemetry()
    arc = Archive.open(snap_path)
    srv = ArchiveServer(arc, telemetry=tel, max_bytes=1 << 30)
    srv.decode(NAMES[3])
    n_reads = len(arc.reader.entry_reads)
    srv.decode(NAMES[3])                     # hot: no further disk touch
    assert len(arc.reader.entry_reads) == n_reads
    c = tel.counters_prefixed("serve.cache.")
    assert c.get("serve.cache.hits", 0) >= 1
    srv.close(close_archives=True)


def test_cache_never_exceeds_ledger_ceiling(snap_path, reference):
    # room for ~2.5 decoded fields: serving all 4 (plus the aux rec) must
    # evict, not blow the ceiling
    ceiling = int(FIELD_NBYTES * 2.5)
    tel = obs.Telemetry()
    ledger = ResidencyLedger(ceiling, telemetry=tel)
    with ArchiveServer(snap_path, ledger=ledger, telemetry=tel) as srv:
        for n in NAMES:
            assert np.array_equal(srv.decode(n), reference[n])
            assert ledger.current <= ceiling
        assert ledger.peak <= ceiling
        assert tel.counters_prefixed("serve.cache.").get(
            "serve.cache.evictions", 0) >= 1
    assert ledger.current == 0               # close releases every charge


def test_cache_rejects_when_everything_pinned():
    ledger = ResidencyLedger(100)
    cache = HotFieldCache(ledger)
    a = np.zeros(20, np.uint8)
    b = np.zeros(90, np.uint8)
    assert cache.put("a", a)
    cache.pin("a")
    # b alone fits the ceiling only if a is evicted — but a is pinned
    assert not cache.put("b", b)
    assert "a" in cache and "b" not in cache
    assert ledger.current <= 100
    cache.unpin("a")
    assert cache.put("b", b)                 # now a may be evicted
    assert "a" not in cache and "b" in cache
    assert ledger.current <= 100


def test_cache_pin_is_refcounted():
    ledger = ResidencyLedger(100)
    cache = HotFieldCache(ledger)
    cache.put("x", np.zeros(60, np.uint8))
    cache.pin("x")
    cache.pin("x")
    cache.unpin("x")
    assert not cache.put("y", np.zeros(80, np.uint8))   # still pinned once
    cache.unpin("x")
    assert cache.put("y", np.zeros(80, np.uint8))


def test_aux_closure_cached_and_reused(snap_path):
    """NAMES[0] depends on NAMES[1]'s conv rec; after serving NAMES[0]
    cold, a repeat decode with an invalidated main key must reuse the
    cached aux closure instead of re-reading NAMES[1] from disk."""
    arc = Archive.open(snap_path)
    srv = ArchiveServer(arc, max_bytes=1 << 30)
    srv.decode(NAMES[0])
    aux_key = ("aux", srv.archive_ids[0], NAMES[1])
    assert aux_key in srv.cache
    srv.cache.invalidate((srv.archive_ids[0], NAMES[0], None))
    n_reads = len(arc.reader.entry_reads)
    srv.decode(NAMES[0])
    reads = arc.reader.entry_reads[n_reads:]
    assert NAMES[1] not in reads             # closure came from the cache
    srv.close(close_archives=True)


# ---------------------------------------------------------------------------
# Ledger-ceiling stress (hypothesis when available, seeded fallback always)
# ---------------------------------------------------------------------------

def _stress_cache(seed: int, ceiling: int) -> None:
    rng = np.random.default_rng(seed)
    ledger = ResidencyLedger(ceiling)
    cache = HotFieldCache(ledger)
    pinned: list = []
    for step in range(200):
        op = rng.integers(0, 4)
        key = int(rng.integers(0, 12))
        if op == 0:
            cache.put(key, np.zeros(int(rng.integers(1, ceiling)), np.uint8))
        elif op == 1:
            cache.get(key)
        elif op == 2:
            cache.pin(key)
            pinned.append(key)
        elif op == 3 and pinned:
            cache.unpin(pinned.pop(int(rng.integers(0, len(pinned)))))
        assert ledger.current <= ceiling, f"step {step}: over ceiling"
        assert cache.resident_bytes == ledger.current
    for k in list(pinned):
        cache.unpin(k)
    cache.clear()
    assert ledger.current == 0


@pytest.mark.parametrize("seed", [0, 1, 7, 42])
def test_cache_stress_seeded(seed):
    _stress_cache(seed, ceiling=1000)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # hypothesis is an optional [dev] extra
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), ceiling=st.integers(64, 4096))
    def test_property_cache_respects_ceiling(seed, ceiling):
        _stress_cache(seed, ceiling)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_cache_respects_ceiling():
        pass


# ---------------------------------------------------------------------------
# ROI requests route through the server
# ---------------------------------------------------------------------------

def test_server_roi_request(snap_path, reference):
    with ArchiveServer(snap_path, max_bytes=1 << 30) as srv:
        roi = (slice(2, 6), slice(0, 8))
        out = srv.decode(NAMES[3], roi=roi)
        assert np.array_equal(out, reference[NAMES[3]][2:6, 0:8])
        # ROI results cache under their own key
        out2 = srv.decode(NAMES[3], roi=roi)
        assert np.array_equal(out2, out)


# ---------------------------------------------------------------------------
# Fault isolation: an injected fault fails the request, not the server
# ---------------------------------------------------------------------------

def test_injected_fault_fails_request_server_keeps_serving(snap_path,
                                                           reference):
    fc = FaultConfig(injector=FaultInjector({"serve.request": 0}))
    with ArchiveServer(snap_path, max_bytes=1 << 30, faults=fc,
                       auto_start=False) as srv:
        doomed = srv.submit(NAMES[3])
        srv.start()
        with pytest.raises(InjectedFault):
            doomed.result(30)
        # same server, next request: serves fine
        ok = srv.decode(NAMES[2])
        assert np.array_equal(ok, reference[NAMES[2]])
        st = srv.stats()
        assert st["counters"].get("serve.request_errors", 0) in (0, 1)


def test_fault_in_batch_fails_only_affected_field(snap_path, reference):
    """One bad field in a coalesced batch must not poison its batchmates."""
    fc = FaultConfig(injector=FaultInjector({"serve.request": 0}))
    srv = ArchiveServer(snap_path, max_bytes=1 << 30, faults=fc,
                        auto_start=False)
    futs = {n: srv.submit(n) for n in NAMES}
    srv.start()
    results, errors = {}, {}
    for n, f in futs.items():
        try:
            results[n] = f.result(30)
        except InjectedFault as e:
            errors[n] = e
    srv.close()
    assert len(errors) == 1                  # exactly one request failed
    for n, out in results.items():
        assert np.array_equal(out, reference[n]), n


def test_unknown_field_fails_cleanly(snap_path):
    with ArchiveServer(snap_path, max_bytes=1 << 30) as srv:
        with pytest.raises(KeyError):
            srv.decode("no_such_field")
        assert srv.running


# ---------------------------------------------------------------------------
# Multi-tenant: several archives behind one server, one ledger
# ---------------------------------------------------------------------------

def test_multi_archive_serving(tmp_path, snap_path, reference):
    other = {n: FIELDS[n] * 2.0 for n in NAMES[:2]}
    p2 = str(tmp_path / "other.nlzs")
    streaming.compress(other, p2, rel_eb=1e-3, config=_cfg())
    ref2 = {n: Archive.open(p2).decode(n) for n in other}
    srv = ArchiveServer({"a": snap_path, "b": p2}, max_bytes=1 << 30,
                        auto_start=False)
    fa = srv.submit(NAMES[0], archive_id="a")
    fb = srv.submit(NAMES[0], archive_id="b")
    srv.start()
    assert np.array_equal(fa.result(30), reference[NAMES[0]])
    assert np.array_equal(fb.result(30), ref2[NAMES[0]])
    with pytest.raises(ValueError):          # ambiguous without an id
        srv.submit(NAMES[0])
    srv.close()


# ---------------------------------------------------------------------------
# Transcode: byte-parity with whole-snapshot recompress, resume, ledger
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("src_version", (1, 2))
def test_transcode_byte_parity_vs_recompress(tmp_path, src_version,
                                             reference):
    src = str(tmp_path / f"src_v{src_version}.nlzs")
    streaming.compress(FIELDS, src, rel_eb=1e-3,
                       config=_cfg(cross_field=CROSS),
                       stream=streaming.StreamConfig(
                           container_version=src_version))
    cfg = _cfg(cross_field=CROSS)
    dst = str(tmp_path / "re.nlzs")
    out = transcode(src, dst, rel_eb=1e-2, config=cfg)
    assert out.field_names == NAMES
    # reference: decode the whole snapshot, recompress under same bounds
    ref_dst = str(tmp_path / "ref.nlzs")
    with Archive.open(src) as a:
        decoded = {n: a.decode(n) for n in NAMES}
    streaming.compress(decoded, ref_dst, rel_eb=1e-2, config=cfg)
    with arc_io.ArchiveReader(dst) as r1, \
            arc_io.ArchiveReader(ref_dst) as r2:
        for n in NAMES:
            assert arc_io.dumps(r1.read_entry(n)) \
                == arc_io.dumps(r2.read_entry(n)), n
    out.close()


def test_transcode_respects_new_bounds(tmp_path, snap_path):
    from repro.core.bounds import ErrorBound
    dst = str(tmp_path / "requal.nlzs")
    out = transcode(snap_path, dst, config=_cfg(cross_field=CROSS),
                    bounds={NAMES[0]: ErrorBound(rel=1e-1, mode="relaxed")},
                    rel_eb=1e-2)
    assert out.entry(NAMES[0])["mode"] == "relaxed"
    assert out.entry(NAMES[1])["mode"] == "strict"
    # re-targeted bound actually holds on the transcoded data
    src_dec = Archive.open(snap_path).decode(NAMES[0])
    re_dec = out.decode(NAMES[0])
    rng = float(src_dec.max() - src_dec.min())
    # relaxed regulation honors the paper's 2x-bound envelope
    assert float(np.abs(re_dec - src_dec).max()) <= 2e-1 * rng * (1 + 1e-6)
    out.close()


def test_transcode_shares_ledger_and_stays_bounded(tmp_path, snap_path):
    ledger = ResidencyLedger(64 << 20)
    dst = str(tmp_path / "led.nlzs")
    out = transcode(snap_path, dst, rel_eb=1e-2,
                    config=_cfg(cross_field=CROSS), ledger=ledger)
    assert out.report["peak_resident_bytes"] <= 64 << 20
    assert ledger.current == 0               # transcode released its charges
    out.close()


def test_transcode_blocked_source_preserves_manifest(tmp_path):
    big = F.make_fields("nyx", shape=(16, 16, 16), seed=3)["temperature"]
    bsrc = streaming.BlockedSource(streaming.DictSource({"huge": big}),
                                   max_block_bytes=big.nbytes // 3)
    src = str(tmp_path / "blocked.nlzs")
    streaming.compress(bsrc, src, rel_eb=1e-3, config=_cfg())
    dst = str(tmp_path / "blocked_re.nlzs")
    out = transcode(src, dst, rel_eb=1e-2, config=_cfg(),
                    bounds={"huge": 1e-2})   # original name expands to blocks
    assert "huge" in out.block_manifest
    assert out.block_manifest == Archive.open(src).block_manifest
    assert out.decode("huge").shape == big.shape
    out.close()


def test_transcode_resume_byte_identical(tmp_path, snap_path):
    cfg = _cfg(cross_field=CROSS)
    whole = str(tmp_path / "whole.nlzs")
    transcode(snap_path, whole, rel_eb=1e-2, config=cfg).close()
    # tear the finished output mid-container, then resume the transcode
    torn = str(tmp_path / "torn.nlzs")
    blob = open(whole, "rb").read()
    open(torn, "wb").write(blob[:int(len(blob) * 0.6)])
    out = transcode(snap_path, torn, rel_eb=1e-2, config=cfg, resume=True)
    assert isinstance(out.report["resumed_fields"], list)
    # per-entry byte identity with the uninterrupted run (the PR 8 resume
    # contract: record order may differ, entry bytes may not), and a
    # sealed, checksum-clean container
    rep = out.verify()
    assert rep["ok"] and rep["sealed"]
    with Archive.open(whole) as ref:
        for n in NAMES:
            assert arc_io.dumps(out.entry(n)) == arc_io.dumps(ref.entry(n)), n
    out.close()


# ---------------------------------------------------------------------------
# Telemetry: spans parent under the server root
# ---------------------------------------------------------------------------

def test_server_spans_parent_to_root(snap_path):
    tel = obs.Telemetry()
    with ArchiveServer(snap_path, max_bytes=1 << 30, telemetry=tel) as srv:
        srv.decode(NAMES[3])
    names = [s.name for s in tel.spans]
    assert "serve" in names
    assert "serve.batch" in names
    root = next(s for s in tel.spans if s.name == "serve")
    batches = [s for s in tel.spans if s.name == "serve.batch"]
    assert all(s.parent == root.id for s in batches)
