"""NeurLZ-JAX: neural-enhanced scientific lossy compression (Jia et al.,
ICS'25) as a first-class feature of a multi-pod JAX training/serving
framework.

Subpackages (imported lazily — ``repro.core``/``repro.compressors`` enable
x64 for FP64 datasets; model/launch paths do not):
    core          the paper's pipeline (enhancer, online training, regulation)
    compressors   SZ3-style / Lorenzo / ZFP-style error-bounded codecs
    kernels       Pallas TPU kernels (+ ops/ref)
    models        the 10 assigned architectures
    configs       arch configs + shape suites
    distributed   sharding rules, elastic re-sharding
    optim         AdamW, schedules, compressed grad sync
    checkpoint    fault-tolerant checkpointing
    data          synthetic fields + token pipeline
    launch        mesh, dryrun, roofline, train, serve
"""
__version__ = "1.0.0"
