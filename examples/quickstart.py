"""NeurLZ quickstart: compress a scientific field with online neural
enhancement, decompress, verify the bound — via the first-class session API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro
from repro.core import metrics
from repro.data import fields

# 1. a synthetic cosmology block (stands in for a Nyx field)
flds = fields.make_fields("nyx", shape=(32, 48, 48), seed=0)
x = flds["dark_matter_density"]

# 2. a compression session: strict 1e-3 value-range-relative bound, the
#    enhancer trains online for 5 epochs during compression
sess = repro.NeurLZ(mode="strict", epochs=5, compressor="szlike")
archive = sess.compress({"dmd": x}, bounds=repro.ErrorBound(rel=1e-3))

# 3. round-trip through disk, then lazy random-access decode
archive.save("/tmp/quickstart.nlz")
with repro.Archive.open("/tmp/quickstart.nlz") as arc:
    out = arc.decode("dmd")
    eb = arc.entry("dmd")["abs_eb"]
    br = arc.bitrate("dmd")["bitrate"]

print(f"max |err|/eb : {np.abs(out.astype(np.float64) - x).max() / eb:.4f}  (must be <= 1)")
print(f"PSNR         : {metrics.psnr(x, out):.2f} dB")
print(f"bitrate      : {br:.3f} bits/value (fp32 raw = 32)")
