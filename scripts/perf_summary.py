"""Summarize the §Perf iteration records (experiments/perf + baselines).

Also diffs two benchmark ledgers (``benchmarks.run --smoke`` writes
``BENCH_PR9.json`` at the repo root)::

    python scripts/perf_summary.py --compare old.json new.json

prints per-row wall-clock deltas and exits nonzero when any timed row
regressed by more than the threshold (default 25%).
"""
import argparse
import json
import sys

CELLS = {
    "A (qwen3-8b train_4k 16x16)": [
        ("A0 baseline", "experiments/dryrun/qwen3-8b_train_4k_single.json"),
        ("A1 skip_uncausal [adopted]",
         "experiments/perf/qwen3-8b_train_4k_single_A1_skipuncausal.json"),
        ("A2 remat=dots [rejected: HBM]",
         "experiments/perf/qwen3-8b_train_4k_single_A2_dots.json"),
        ("A3 seq-shard inputs [refuted]",
         "experiments/perf/qwen3-8b_train_4k_single_A3_seqshard.json"),
        ("A4 microbatch=16",
         "experiments/perf/qwen3-8b_train_4k_single_A4_mb16.json"),
        ("A5 A1+sp_residual",
         "experiments/perf/qwen3-8b_train_4k_single_A5_skipunc_sp.json"),
        ("A6 A5+mb2",
         "experiments/perf/qwen3-8b_train_4k_single_A6_skipunc_sp_mb2.json"),
        ("A7 A1+mb2 [rejected: HBM]",
         "experiments/perf/qwen3-8b_train_4k_single_A7_skipunc_mb2.json"),
    ],
    "B (deepseek-moe train_4k 2x16x16)": [
        ("B0 baseline group=2048",
         "experiments/dryrun/deepseek-moe-16b_train_4k_multi.json"),
        ("B1 group=256 [adopted]",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B1_group256.json"),
        ("B2 B1+seq-shard [refuted]",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B2_group256_seqshard.json"),
        ("B3 B1+remat=dots",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B3_group256_dots.json"),
        ("B4 B1+sp_residual",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B4_group256_sp.json"),
    ],
    "C (neurlz_enhance 16x16)": [
        ("C0 baseline pjit+vmap",
         "experiments/dryrun/neurlz_enhance_na_single.json"),
        ("C1 shard_map [adopted]",
         "experiments/perf/neurlz_enhance_na_single_C1_shardmap.json"),
    ],
}


REGRESSION_PCT = 25.0


def compare_ledgers(old_path: str, new_path: str,
                    threshold_pct: float = REGRESSION_PCT) -> int:
    """Per-row wall-clock deltas between two ``benchmarks.run`` ledgers.

    Rows match by ``name``; a row only counts toward the regression verdict
    when both sides carry a positive ``us_per_call`` (0.0 rows are
    informational — rate/quality tables, artifact pointers).  Returns the
    number of rows regressed past ``threshold_pct``.
    """
    old = {r["name"]: r for r in json.load(open(old_path))["rows"]}
    new = {r["name"]: r for r in json.load(open(new_path))["rows"]}
    regressed = added = removed = 0
    print(f"{'row':44s} {'old_us':>12s} {'new_us':>12s} {'delta':>8s}")
    for name, nr in new.items():
        orow = old.get(name)
        n = float(nr.get("us_per_call") or 0.0)
        if orow is None:
            # Present only in the new ledger (a benchmark module grew a
            # row, or a new module joined --smoke): informational, never a
            # failure — first comparison against an old ledger must pass.
            added += 1
            print(f"{name:44s} {'(added)':>12s} {n:12.1f}")
            continue
        o = float(orow.get("us_per_call") or 0.0)
        if o <= 0.0 or n <= 0.0:
            continue
        delta = 100.0 * (n - o) / o
        flag = ""
        if delta > threshold_pct:
            regressed += 1
            flag = f"  << REGRESSION (> {threshold_pct:g}%)"
        print(f"{name:44s} {o:12.1f} {n:12.1f} {delta:+7.1f}%{flag}")
    for name, orow in old.items():
        if name not in new:
            removed += 1
            o = float(orow.get("us_per_call") or 0.0)
            print(f"{name:44s} {o:12.1f} {'(removed)':>12s}")
    if added or removed:
        print(f"\n{added} row(s) added, {removed} removed (informational)")
    if regressed:
        print(f"\n{regressed} row(s) regressed past {threshold_pct:g}% "
              "wall-clock")
    return regressed


def summarize_cells():
    for cell, rows in CELLS.items():
        print(f"\n## {cell}")
        print(f"{'iteration':38s} {'comp_ms':>9s} {'mem_ms':>9s} "
              f"{'coll_ms':>9s} {'HBM_GiB':>8s} {'useful':>7s}")
        for label, path in rows:
            try:
                r = json.load(open(path))
            except FileNotFoundError:
                print(f"{label:38s} (missing)")
                continue
            t = r["roofline"]
            u = r.get("useful_compute_ratio")
            print(f"{label:38s} {t['compute_s']*1e3:9.1f} "
                  f"{t['memory_s']*1e3:9.1f} {t['collective_s']*1e3:9.1f} "
                  f"{r['memory']['peak_hbm_bytes']/2**30:8.2f} "
                  f"{u if u is None else format(u, '.3f'):>7}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--compare", nargs=2, metavar=("OLD", "NEW"),
                    help="diff two benchmark ledger JSONs "
                         "(benchmarks.run --smoke output)")
    ap.add_argument("--threshold", type=float, default=REGRESSION_PCT,
                    help="regression threshold in percent "
                         f"(default {REGRESSION_PCT:g})")
    args = ap.parse_args()
    if args.compare:
        regressed = compare_ledgers(args.compare[0], args.compare[1],
                                    threshold_pct=args.threshold)
        sys.exit(1 if regressed else 0)
    summarize_cells()


if __name__ == "__main__":
    main()
