"""Crash-safety of the NLZSTRM2 container: torn-write salvage, checksum
verification, resumable compression, and typed corruption errors.

The torn-write matrix is the core durability contract: a container killed
at *any* byte offset must (a) refuse to open as sealed with a typed
:class:`CorruptArchiveError`, and (b) salvage every fully-written entry
**bit-identically** under ``repair=True``.  Resume then extends salvage to
the compression side: re-running the same configuration over a torn
container must produce entries byte-identical to an uninterrupted run.
"""
import io
import os

import numpy as np
import pytest

import repro
from repro import core, streaming
from repro.compressors import codec
from repro.core import archive as A


@pytest.fixture(params=["zlib", "zstd"])
def codec_name(request):
    if request.param == "zstd" and not codec.HAVE_ZSTD:
        pytest.skip("zstandard not installed")
    codec.set_default_codec(request.param)
    yield request.param
    codec.set_default_codec(None)


def _snapshot(n_fields: int = 3) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(7)
    return {f"f{i}": np.cumsum(rng.standard_normal((3, 8, 8)),
                               axis=0).astype(np.float32)
            for i in range(n_fields)}


def _stream_cfg(**kw):
    kw.setdefault("epochs", 1)
    return core.NeurLZConfig(mode="strict", engine="streaming",
                             group_size=1, **kw)


def _write_container(tmp_path, fields=None):
    p = os.fspath(tmp_path / "snap.nlz")
    streaming.compress(fields or _snapshot(), p, 1e-3, config=_stream_cfg())
    return p


# -- torn-write matrix -------------------------------------------------------

def test_torn_write_matrix(tmp_path, codec_name):
    """Cut the container at a sweep of byte offsets: salvage must recover
    exactly the fully-sealed entries, each bit-identical to the
    uninterrupted container's record."""
    p = _write_container(tmp_path)
    data = open(p, "rb").read()
    with A.ArchiveReader(p) as r:
        full = {n: r.read_entry(n) for n in r.entries}
        # index offsets are record starts; payload_len excludes the prefix
        ends = {n: off + A._V2_PREFIX + ln
                for n, (off, ln) in r.entries.items()}

    torn = os.fspath(tmp_path / "torn.nlz")
    # Stride plus every record-end boundary (the interesting edges).
    cuts = sorted(set(range(9, len(data) - 1, max(1, len(data) // 40)))
                  | set(ends.values()))
    for cut in cuts:
        with open(torn, "wb") as f:
            f.write(data[:cut])
        # A torn container never opens as sealed.
        with pytest.raises(A.CorruptArchiveError):
            A.ArchiveReader(torn).close()
        with A.ArchiveReader(torn, repair=True) as r:
            assert r.salvaged
            expect = {n for n, e in ends.items() if e <= cut}
            assert set(r.entries) == expect, f"cut={cut}"
            for n in expect:
                assert A.dumps(r.read_entry(n)) == A.dumps(full[n])


def test_salvage_resyncs_past_corrupt_record(tmp_path):
    """Damage *inside* one record must not take down the records after it:
    the scanner resyncs on the next record marker."""
    p = _write_container(tmp_path)
    with A.ArchiveReader(p) as r:
        offsets = dict(r.entries)
    data = bytearray(open(p, "rb").read())
    victim, (off, ln) = sorted(offsets.items(), key=lambda kv: kv[1][0])[0]
    for i in range(off + 4, off + 8):    # stomp the first entry's payload
        data[i] ^= 0xFF
    torn = os.fspath(tmp_path / "bitrot.nlz")
    open(torn, "wb").write(bytes(data))
    with A.ArchiveReader(torn, repair=True) as r:
        assert victim not in r.entries
        assert set(r.entries) == set(offsets) - {victim}
        assert any(d["offset"] <= off for d in r.damage)


def test_verify_clean_container(tmp_path, codec_name):
    p = _write_container(tmp_path)
    rep = A.verify_container(p)
    assert rep["sealed"] and rep["ok"]
    assert all(e["ok"] and e["error"] is None
               for e in rep["entries"].values())


def test_verify_pinpoints_flipped_bit(tmp_path, codec_name):
    p = _write_container(tmp_path)
    with A.ArchiveReader(p) as r:
        offsets = dict(r.entries)
    victim, (off, ln) = sorted(offsets.items(), key=lambda kv: kv[1][0])[1]
    data = bytearray(open(p, "rb").read())
    data[off + A._V2_PREFIX + ln // 2] ^= 0x01   # flipped bit mid-payload
    open(p, "wb").write(bytes(data))
    rep = A.verify_container(p)
    assert rep["sealed"] and not rep["ok"]
    for name, e in rep["entries"].items():
        if name == victim:
            assert not e["ok"] and "checksum" in e["error"]
            assert e["offset"] == off
        else:
            assert e["ok"], name


def test_archive_handle_verify_and_repair(tmp_path):
    p = _write_container(tmp_path)
    with repro.Archive.open(p) as arc:
        assert not arc.salvaged
        rep = arc.verify()
        assert rep["ok"] and rep["sealed"]
        full = {n: arc.decode(n) for n in arc.field_names}
    data = open(p, "rb").read()
    torn = os.fspath(tmp_path / "torn.nlz")
    open(torn, "wb").write(data[: len(data) // 2])
    with repro.Archive.open(torn, repair=True) as arc:
        assert arc.salvaged
        assert arc.field_names            # at least one entry survived
        for n in arc.field_names:
            np.testing.assert_array_equal(arc.decode(n), full[n])


# -- resume ------------------------------------------------------------------

def _torn_copy(p, tmp_path, frac):
    data = open(p, "rb").read()
    torn = os.fspath(tmp_path / "resume.nlz")
    open(torn, "wb").write(data[: int(len(data) * frac)])
    return torn


@pytest.mark.parametrize("frac", [0.2, 0.55, 0.9])
def test_resume_byte_identical_to_uninterrupted(tmp_path, codec_name, frac):
    fields = _snapshot()
    sess = repro.NeurLZ(config=_stream_cfg())
    p = os.fspath(tmp_path / "full.nlz")
    arc_full = sess.compress_to(fields, p, rel_eb=1e-3)
    torn = _torn_copy(p, tmp_path, frac)

    arc = sess.compress_to(fields, torn, rel_eb=1e-3, resume=True)
    assert A.dumps(arc.to_dict()["fields"]) == \
        A.dumps(arc_full.to_dict()["fields"])
    done = set(arc.report["resumed_fields"])
    assert done <= set(fields)
    rep = arc.verify()
    assert rep["ok"] and rep["sealed"]
    arc.close()
    arc_full.close()


def test_resume_into_fresh_sink_is_plain_run(tmp_path):
    """resume=True against a nonexistent / empty sink degrades to a normal
    run (nothing to salvage)."""
    fields = _snapshot(2)
    sess = repro.NeurLZ(config=_stream_cfg())
    p = os.fspath(tmp_path / "fresh.nlz")
    arc = sess.compress_to(fields, p, rel_eb=1e-3, resume=True)
    assert arc.report["resumed_fields"] == []
    assert arc.verify()["ok"]
    arc.close()


def test_resume_config_mismatch_is_hard_error(tmp_path):
    fields = _snapshot(2)
    sess = repro.NeurLZ(config=_stream_cfg())
    p = os.fspath(tmp_path / "full.nlz")
    sess.compress_to(fields, p, rel_eb=1e-3).close()
    torn = _torn_copy(p, tmp_path, 0.6)
    other = repro.NeurLZ(config=_stream_cfg(epochs=2))
    with pytest.raises(ValueError, match="epochs"):
        other.compress_to(fields, torn, rel_eb=1e-3, resume=True)
    # different bound: also a mismatch, never silent
    with pytest.raises(ValueError, match="rel_eb|abs_eb"):
        sess.compress_to(fields, torn, rel_eb=1e-2, resume=True)


def test_resume_stale_fields_is_hard_error(tmp_path):
    fields = _snapshot(2)
    sess = repro.NeurLZ(config=_stream_cfg())
    p = os.fspath(tmp_path / "full.nlz")
    sess.compress_to(fields, p, rel_eb=1e-3).close()
    with pytest.raises(ValueError, match="f1"):
        sess.compress_to({"f0": fields["f0"]}, p, rel_eb=1e-3, resume=True)


# -- typed corruption errors / sniffing --------------------------------------

def test_is_streaming_archive_robust_to_tiny_files(tmp_path):
    for n in range(8):                   # every length below the magic size
        p = os.fspath(tmp_path / f"tiny{n}")
        open(p, "wb").write(b"\x00" * n)
        assert A.is_streaming_archive(p) is False
    assert A.is_streaming_archive(os.fspath(tmp_path / "absent")) is False
    assert A.is_streaming_archive(b"NLZSTRM1") is True
    assert A.is_streaming_archive(b"NLZSTRM2") is True


@pytest.mark.parametrize("blob", [
    b"", b"NL", b"NLZSTRM2", b"NLZSTRM2" + b"\x00" * 4,
    b"garbage-not-a-container-at-all", b"NLZSTRM9" + b"\x00" * 64,
])
def test_corrupt_open_raises_typed_error(tmp_path, blob):
    p = os.fspath(tmp_path / "bad.nlz")
    open(p, "wb").write(blob)
    with pytest.raises((A.CorruptArchiveError, ValueError)) as ei:
        A.ArchiveReader(p).close()
    if isinstance(ei.value, A.CorruptArchiveError):
        assert ei.value.path == p        # offset context travels on the type


def test_corrupt_error_carries_offset(tmp_path):
    p = _write_container(tmp_path)
    with A.ArchiveReader(p) as r:
        victim, (off, ln) = sorted(r.entries.items(),
                                   key=lambda kv: kv[1][0])[0]
    data = bytearray(open(p, "rb").read())
    data[off] ^= 0xFF
    open(p, "wb").write(bytes(data))
    with A.ArchiveReader(p) as r:
        with pytest.raises(A.CorruptArchiveError) as ei:
            r.read_entry(victim)
        assert ei.value.offset is not None
        assert str(ei.value.offset) in str(ei.value)


# -- v1 compatibility / appender mechanics -----------------------------------

def test_v1_containers_stay_readable(tmp_path):
    p = os.fspath(tmp_path / "v1.nlz")
    app = A.ArchiveAppender(p, version=1)
    app.add_entry("a", {"conv": {"blob": b"x" * 32}})
    app.add_entry("b", {"conv": {"blob": b"y" * 16}})
    app.finalize({"field_order": ["a", "b"]})
    assert A.is_streaming_archive(p)
    with A.ArchiveReader(p) as r:
        assert r.version == 1
        assert r.read_entry("a")["conv"]["blob"] == b"x" * 32
    rep = A.verify_container(p)          # v1 has no checksums: framing only
    assert rep["sealed"] and rep["ok"]


def test_v2_default_and_prelude_roundtrip():
    buf = io.BytesIO()
    app = A.ArchiveAppender(buf, prelude={"config_sig": {"epochs": 1}})
    app.add_entry("a", {"conv": {"blob": b"z" * 8}})
    app.finalize({"field_order": ["a"]})
    buf.seek(0)
    with A.ArchiveReader(buf) as r:
        assert r.version == 2
        assert r.read_prelude()["config_sig"] == {"epochs": 1}


def test_appender_rewind_drops_partial_record():
    buf = io.BytesIO()
    app = A.ArchiveAppender(buf)
    app.add_entry("a", {"conv": {"blob": b"A" * 24}})
    boundary = app.bytes_written
    app.add_entry("junk", {"conv": {"blob": b"J" * 100}})
    app.rewind(boundary)
    assert app.bytes_written == boundary and "junk" not in app.entries
    app.add_entry("b", {"conv": {"blob": b"B" * 24}})
    app.finalize({"field_order": ["a", "b"]})
    buf.seek(0)
    with A.ArchiveReader(buf) as r:
        assert list(r.entries) == ["a", "b"]
        assert r.read_entry("b")["conv"]["blob"] == b"B" * 24
    assert A.verify_container(buf)["ok"]


@pytest.mark.parametrize("durability", ["none", "flush", "fsync"])
def test_durability_levels_produce_sealed_containers(tmp_path, durability):
    p = os.fspath(tmp_path / f"{durability}.nlz")
    app = A.ArchiveAppender(p, durability=durability)
    app.add_entry("a", {"conv": {"blob": b"d" * 8}})
    app.finalize({"field_order": ["a"]})
    assert A.verify_container(p)["ok"]


def test_bad_appender_knobs_raise():
    with pytest.raises(ValueError):
        A.ArchiveAppender(io.BytesIO(), version=3)
    with pytest.raises(ValueError):
        A.ArchiveAppender(io.BytesIO(), durability="sometimes")
    with pytest.raises(ValueError):
        A.ArchiveAppender(io.BytesIO(), checksum="md5")
    with pytest.raises(ValueError):     # v1 records can't carry a prelude
        A.ArchiveAppender(io.BytesIO(), version=1, prelude={"x": 1})
