"""Learning-rate schedules (pure functions of the step index)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.0):
    """Cosine annealing from ``base_lr`` to ``base_lr * min_frac`` — the
    paper's enhancer schedule (initial 1e-2, cosine over 100 epochs)."""
    def lr(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / max(total_steps, 1)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (min_frac + (1.0 - min_frac) * cos)
    return lr


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_frac: float = 0.1):
    """Linear warmup then cosine decay — the LM trainer schedule."""
    def lr(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup_steps, warm, cos)
    return lr
