"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; the vision tower is a STUB (input_specs provides
precomputed patch embeddings, 1152 image positions = 2 anyres tiles x 576)
[hf:llava-hf/llava-v1.6-34b family; unverified]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=20480, vocab_size=64000,
    act="silu", rope_theta=5e6, input_kind="multimodal", frontend_tokens=1152,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab_size=256, frontend_tokens=8,
                               dtype="float32")
