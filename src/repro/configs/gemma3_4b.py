"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
— 5:1 local:global interleave, 128k context, head_dim=256, qk-norm
[hf:google/gemma-3-4b-pt family; unverified]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense", n_layers=34, d_model=2560, n_heads=8,
    n_kv_heads=4, head_dim=256, d_ff=10240, vocab_size=262144, act="gelu",
    qk_norm=True, rope_theta=1e4, tie_embeddings=True, embed_scale=True,
    window_size=1024, pattern_local=5, pattern_global=1,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=8, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab_size=256, window_size=16,
                               pattern_local=3, pattern_global=1,
                               dtype="float32")
