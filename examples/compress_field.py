"""End-to-end NeurLZ driver (the paper's workload): multi-field block,
cross-field learning, strict error regulation, archive on disk, full
validation report.

    PYTHONPATH=src python examples/compress_field.py [--dataset nyx]
        [--shape 32,48,48] [--eb 1e-3] [--epochs 8] [--mode strict]
"""
import argparse
import os
import resource
import sys
import tempfile

import numpy as np

from repro import compressors as C
from repro import core
from repro import streaming
from repro.compressors import registry
from repro.core import metrics
from repro.data import fields as F


def list_compressors() -> None:
    """Print the compressor registry (names, capabilities, archive kinds)."""
    print(f"{'name':18s} {'kind':10s} {'batchable':9s} {'dtypes':18s} description")
    for e in registry.entries():
        dts = ",".join(e.dtypes)
        print(f"{e.name:18s} {e.kind:10s} {str(e.batchable):9s} {dts:18s} "
              f"{e.description}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="nyx",
                    choices=["nyx", "miranda", "hurricane"])
    ap.add_argument("--shape", default="32,48,48")
    ap.add_argument("--eb", type=float, default=1e-3)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--mode", default="strict",
                    choices=["strict", "relaxed", "unregulated"])
    ap.add_argument("--compressor", default="szlike",
                    choices=registry.names(),
                    help="conventional stage (any registered compressor)")
    ap.add_argument("--list-compressors", action="store_true",
                    help="print the compressor registry and exit")
    ap.add_argument("--engine", default="batched",
                    choices=["serial", "batched", "streaming"],
                    help="batched = multi-field fused-dispatch engine; "
                         "streaming = bounded-memory pipeline + async "
                         "archive writer (both bit-identical to serial)")
    ap.add_argument("--max-resident-mb", type=float, default=0.0,
                    help="streaming engine residency budget in MiB "
                         "(0 = track peak only, no ceiling)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.list_compressors:
        list_compressors()
        return

    shape = tuple(int(s) for s in args.shape.split(","))
    flds = F.make_fields(args.dataset, shape=shape, seed=0)
    cross = F.DEFAULT_CROSS_FIELD[args.dataset]

    cfg = core.NeurLZConfig(compressor=args.compressor, mode=args.mode,
                            epochs=args.epochs, cross_field=cross,
                            engine=args.engine,
                            max_resident_bytes=int(args.max_resident_mb
                                                   * 2**20))
    print(f"[compress] {args.dataset} {shape} eb={args.eb} mode={args.mode} "
          f"epochs={args.epochs} cross_field=on engine={args.engine}")
    path = args.out or os.path.join(tempfile.gettempdir(),
                                    f"{args.dataset}.nlz")
    if args.engine == "streaming":
        # Full out-of-core path: incremental container straight to disk.
        report = streaming.compress(flds, path, rel_eb=args.eb, config=cfg)
        arc = core.load(path)
        nbytes = report["bytes_written"]
        print(f"[resident] pipeline peak {report['peak_resident_bytes']/2**20:.2f} MiB"
              + (f" (budget {cfg.max_resident_bytes/2**20:.2f} MiB)"
                 if cfg.max_resident_bytes else " (no ceiling)")
              + f", writer busy {report['writer_busy_s']:.2f}s")
    else:
        arc = core.compress(flds, rel_eb=args.eb, config=cfg)
        nbytes = core.save(path, arc)
    cs = arc["timing"].get("conv_stage")
    if cs:
        print(f"[conv]     {cs['fields']} fields -> {cs['groups']} groups, "
              f"{cs['calls']} compressor calls "
              f"({cs['batched_fields']} batched / "
              f"{cs['fallback_fields']} per-field), {cs['conv_s']:.2f}s")
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    rss_b = rss if sys.platform == "darwin" else rss * 1024
    print(f"[archive]  {path}  ({nbytes/2**20:.2f} MiB on disk, "
          f"process peak RSS {rss_b/2**20:.0f} MiB)")

    dec_engine = "serial" if args.engine == "streaming" else args.engine
    # The streaming branch already loaded (and reassembled) the archive from
    # disk above; the others decode from disk here to prove the round-trip.
    arc_disk = arc if args.engine == "streaming" else core.load(path)
    dec = core.decompress(arc_disk, engine=dec_engine)
    raw = sum(v.nbytes for v in flds.values())
    total = sum(arc["bitrate"][n]["total_bytes"] for n in flds)
    print(f"[totals]   raw {raw/2**20:.1f} MiB -> {total/2**20:.2f} MiB "
          f"(CR {raw/total:.1f}x)")
    for name, x in flds.items():
        eb = arc["fields"][name]["abs_eb"]
        err = np.abs(dec[name].astype(np.float64) - x.astype(np.float64)).max()
        conv = C.decompress(arc["fields"][name]["conv"])
        br = arc["bitrate"][name]
        print(f"  {name:22s} maxerr/eb={err/eb:6.3f}  "
              f"PSNR {metrics.psnr(x, conv):6.2f} -> {metrics.psnr(x, dec[name]):6.2f} dB  "
              f"bitrate {br['bitrate']:6.3f} b/val")
        limit = eb if args.mode == "strict" else (
            2 * eb if args.mode == "relaxed" else np.inf)
        assert err <= limit * (1 + 1e-9), "bound violated!"
    print("[ok] all error bounds verified")


if __name__ == "__main__":
    main()
