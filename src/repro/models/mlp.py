"""Gated MLP (SwiGLU / GeGLU)."""
from __future__ import annotations

import jax

from ..distributed.sharding import constrain
from .layers import activation, dense_init


def init(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate_in": dense_init(ks[0], d_model, d_ff, dtype),
        "w_up_in": dense_init(ks[1], d_model, d_ff, dtype),
        "w_down_out": dense_init(ks[2], d_ff, d_model, dtype),
    }


def forward(p, x, act: str = "silu"):
    g = activation(act)(x @ p["w_gate_in"])
    h = g * (x @ p["w_up_in"])
    h = constrain(h, ("batch", None, "model"))
    return h @ p["w_down_out"]
