"""AdamW math, schedules, compressed gradient sync."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         warmup_cosine)
from repro.optim import grad_compress as GC


def test_adamw_matches_reference_math():
    p = {"w": jnp.asarray([[1.0, -2.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.5, 0.25]], jnp.float32)}
    st = adamw_init(p)
    p2, st2 = adamw_update(g, st, p, lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    m = 0.1 * np.array([[0.5, 0.25]])
    v = 0.001 * np.array([[0.25, 0.0625]])
    mhat, vhat = m / 0.1, v / 0.001
    expect = np.array([[1.0, -2.0]]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-5)
    assert int(st2.step) == 1


def test_grad_clip():
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0, jnp.float32)}
    st = adamw_init(p)
    p2, _ = adamw_update(g, st, p, lr=0.1, grad_clip_norm=1.0)
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_schedules_endpoints():
    lr = cosine_schedule(1e-2, 100)
    assert abs(float(lr(jnp.asarray(0))) - 1e-2) < 1e-9
    assert float(lr(jnp.asarray(100))) < 1e-6
    wu = warmup_cosine(1e-3, 10, 100)
    assert float(wu(jnp.asarray(0))) == 0.0
    assert abs(float(wu(jnp.asarray(10))) - 1e-3) < 1e-9


def test_quantize_ef_error_feedback_accumulates():
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((64, 64)), jnp.float32)}
    ef = GC.init_ef(g)
    q, s, ef2 = GC.quantize_ef(g, ef, bits=8)
    deq = GC.dequantize(q, s)
    err = np.abs(np.asarray(deq["w"]) - np.asarray(g["w"])).max()
    scale = float(s["w"])
    assert err <= scale * 0.5 + 1e-7      # within half a quantization step
    # ef carries exactly the quantization error
    np.testing.assert_allclose(np.asarray(ef2["w"]),
                               np.asarray(g["w"]) - np.asarray(deq["w"]),
                               atol=1e-7)


def test_compressed_psum_under_shard_map():
    import jax
    from jax.sharding import PartitionSpec as P

    if jax.device_count() < 1:
        return
    from repro.launch.mesh import mesh_kwargs
    mesh = jax.make_mesh((1,), ("pod",), **mesh_kwargs(1))
    g = {"w": jnp.ones((8, 8), jnp.float32) * 0.5}
    ef = GC.init_ef(g)

    def f(g, ef):
        return GC.compressed_psum(g, ef, "pod", bits=8)

    out, ef2 = jax.experimental.shard_map.shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_rep=False)(g, ef)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.5, atol=0.01)


def test_neurlz_grad_archive_compresses():
    rng = np.random.default_rng(0)
    g = {"layers": {"w_in": jnp.asarray(
        np.cumsum(rng.standard_normal((64, 128)), 0), jnp.float32)}}
    rep = GC.neurlz_grad_archive(g, rel_eb=1e-3)
    assert rep["ratio"] > 1.5, rep["ratio"]
