"""Mamba2 (SSD) block — chunked parallel scan, TPU-native.

The selective-state-space recurrence

    h_t = exp(Δ_t A) h_{t-1} + Δ_t B_t x_t,      y_t = C_t h_t + D x_t

is evaluated chunkwise (Dao & Gu, 2024): within a chunk the output is a
masked attention-like score matrix (parallel, MXU-friendly); across chunks a
``lax.scan`` carries the [H, N, P] state.  Chunks are processed sequentially
so the per-device peak is one chunk's score tensor (not L²) — the
long_500k decode cells rely on the O(1)-state decode path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import dense_init, rmsnorm


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_headdim


def init(key, cfg, dtype):
    d = cfg.d_model
    di = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    k = cfg.ssm_conv
    ks = jax.random.split(key, 4)
    conv_ch = di + 2 * n
    return {
        # order: [z (gate) | x | B | C | dt]
        "w_in": dense_init(ks[0], d, 2 * di + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (k, conv_ch), jnp.float32)
                   * (1.0 / np.sqrt(k))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) ∈ (-∞,0)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[2], di, d, dtype),
    }


def _split_proj(p, cfg, x):
    di = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    zxbcdt = x @ p["w_in"]
    z, xs, b, c, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n],
                                axis=-1)
    return z, xs, b, c, dt


def _causal_conv(u, w, b):
    """u: [B, L, C]; w: [K, C] depthwise causal conv."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out + b[None, None])


def forward(p, cfg, x, chunk: int = 128):
    """x: [B, L, D] -> [B, L, D]."""
    bsz, L, _ = x.shape
    di, n, h, pdim = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg), cfg.ssm_headdim
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    z, xs, bmat, cmat, dt = _split_proj(p, cfg, x)
    xbc = _causal_conv(jnp.concatenate([xs, bmat, cmat], -1), p["conv_w"], p["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)

    xh = xs.reshape(bsz, L, h, pdim).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])          # [B,L,H]
    a = -jnp.exp(p["A_log"])                                             # [H]
    loga = dt * a[None, None]                                            # [B,L,H] ≤ 0
    bmat = bmat.astype(jnp.float32)
    cmat = cmat.astype(jnp.float32)

    # chunked views
    xc = xh.reshape(bsz, nc, chunk, h, pdim)
    dtc = dt.reshape(bsz, nc, chunk, h)
    lac = loga.reshape(bsz, nc, chunk, h)
    bc = bmat.reshape(bsz, nc, chunk, n)
    cc = cmat.reshape(bsz, nc, chunk, n)

    cum = jnp.cumsum(lac, axis=2)                                        # [B,nc,cl,H]
    total = cum[:, :, -1]                                                # [B,nc,H]

    def chunk_step(state, inp):
        xck, dtk, lck, cumk, totk, bk, ck = inp
        # inter-chunk: y_i += C_i · (exp(cum_i) * state_in)
        decay_in = jnp.exp(cumk)                                         # [B,cl,H]
        y_inter = jnp.einsum("bln,bhnp,blh->blhp", ck, state, decay_in)
        # intra-chunk: scores[i,j] = (C_i·B_j) exp(cum_i − cum_j) dt_j, j ≤ i
        cb = jnp.einsum("bin,bjn->bij", ck, bk)                          # [B,cl,cl]
        gap = cumk[:, :, None, :] - cumk[:, None, :, :]                  # [B,i,j,H]
        i_idx = jnp.arange(xck.shape[1])
        causal = (i_idx[:, None] >= i_idx[None, :])[None, :, :, None]
        w = jnp.where(causal, jnp.exp(gap), 0.0) * cb[..., None]         # [B,i,j,H]
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", w, dtk, xck)
        # state update: S' = exp(total) S + Σ_j exp(total − cum_j) dt_j B_j ⊗ x_j
        wstate = jnp.exp(totk[:, None] - cumk) * dtk                     # [B,cl,H]
        s_new = jnp.einsum("bjn,bjh,bjhp->bhnp", bk, wstate, xck)
        state = jnp.exp(totk)[:, :, None, None] * state + s_new
        return state, y_inter + y_intra

    state0 = jnp.zeros((bsz, h, n, pdim), jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dtc, lac, cum, total, bc, cc))
    _, ys = jax.lax.scan(chunk_step, state0, inputs)                     # [nc,B,cl,H,P]
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, L, h, pdim)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(bsz, L, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    return y @ p["w_out"]


def init_cache(cfg, batch: int, dtype):
    di, n, h = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    return {
        "state": jnp.zeros((batch, h, n, cfg.ssm_headdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
    }


def decode_step(p, cfg, x, cache):
    """x: [B,1,D] -> ([B,1,D], new_cache).  O(1) state decode."""
    bsz = x.shape[0]
    di, n, h, pdim = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg), cfg.ssm_headdim
    z, xs, bmat, cmat, dt = _split_proj(p, cfg, x)
    xbc = jnp.concatenate([xs, bmat, cmat], -1)                          # [B,1,C]
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)                 # [B,K,C]
    conv_out = jax.nn.silu((hist * p["conv_w"][None]).sum(1) + p["conv_b"])
    xs, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    xh = xs.reshape(bsz, h, pdim).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])   # [B,H]
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * a[None])                                       # [B,H]
    s = cache["state"] * decay[:, :, None, None]
    s = s + jnp.einsum("bn,bh,bhp->bhnp", bmat.astype(jnp.float32), dt1, xh)
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), s)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    return y @ p["w_out"], {"state": s, "conv": hist[:, 1:]}
