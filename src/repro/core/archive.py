"""NeurLZ archive serialization (paper Fig. 2 bottom: file format).

Layout per field: conventional compressed payload ‖ enhancer weights
(dataset-precision floats, zstd'd) ‖ outlier coordinates (strict mode) ‖
normalization stats + header.  msgpack binary container, numpy arrays as
typed blobs.  ``nbytes`` accounting matches what lands on disk.

Three container formats, versioned side by side:

* **whole-dict** (original) — one msgpack blob for the entire archive dict
  (:func:`save` / :func:`load`).
* **streaming v1** (``NLZSTRM1``) — an append-able record container written
  incrementally by the streaming pipeline (:class:`ArchiveAppender`): an
  8-byte magic, then length-prefixed msgpack records (one per field entry,
  in completion order), then an index footer record mapping field name →
  (offset, length) plus snapshot metadata, the footer's own offset, and the
  magic again as a trailer.  :class:`ArchiveReader` seeks the footer and
  decodes one field at a time, so a decoder never has to hold the whole
  archive in memory.  Field *entries* are byte-identical to the whole-dict
  format's — only the container differs — and :func:`repro.core.load`
  sniffs the magic so both formats load through the same call.
* **streaming v2** (``NLZSTRM2``, default) — the durable container.  Same
  record/footer/trailer topology as v1, but every record is
  *self-delimiting*: an 8-byte sync marker, a one-byte checksum-algorithm
  flag, the payload length, and a per-record checksum (CRC-32 via zlib by
  default; CRC-32C when the optional ``crc32c`` wheel is installed and
  requested) precede the msgpack payload.  An optional **prelude** record
  right after the magic carries the snapshot's static metadata (field
  order, shapes, compressor, aux map), so a container whose footer was
  never written — a crashed run — still knows what it holds.  The
  recovery scanner (:func:`scan_container` / ``ArchiveReader(...,
  repair=True)``) walks a footerless or truncated container record by
  record, resynchronizing on the sync marker past torn or corrupt bytes,
  and salvages every checksum-intact entry; :func:`verify_container`
  checks a sealed container entry by entry and pinpoints corruption by
  name and offset.  Reads on this format are checksum-verified; a bad
  record raises :class:`CorruptArchiveError` with offset context.  The
  :class:`ArchiveAppender` ``durability`` policy controls how eagerly
  records reach disk (``"none"`` — buffered, ``"flush"`` — per-entry
  flush, ``"fsync"`` — per-entry flush + fsync).
"""
from __future__ import annotations

import io
import os
import struct
import zlib

import msgpack
import numpy as np

from ..compressors import codec


def _default(obj):
    if isinstance(obj, np.ndarray):
        return {b"__nd__": True, b"dtype": str(obj.dtype), b"shape": list(obj.shape),
                b"data": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj)}")


def _hook(obj):
    if b"__nd__" in obj:
        return np.frombuffer(obj[b"data"], dtype=obj[b"dtype"]).reshape(obj[b"shape"]).copy()
    return obj


def dumps(obj) -> bytes:
    return msgpack.packb(obj, default=_default, use_bin_type=True)


def loads(data: bytes):
    return msgpack.unpackb(data, object_hook=_hook, raw=False, strict_map_key=False)


def save(path: str, obj) -> int:
    data = dumps(obj)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)


def load(path: str):
    with open(path, "rb") as f:
        return loads(f.read())


# ---------------------------------------------------------------------------
# Streaming container (v1 + durable v2): append-able records + index footer
# ---------------------------------------------------------------------------

STREAM_MAGIC = b"NLZSTRM1"
STREAM_MAGIC_V2 = b"NLZSTRM2"
_MAGICS = (STREAM_MAGIC, STREAM_MAGIC_V2)
_LEN = struct.Struct("<Q")

# v2 record = SYNC(8) ‖ <BQI>(checksum-algo flag, payload length, checksum)
# ‖ msgpack payload.  The sync marker lets the salvage scanner resynchronize
# past torn bytes; the flag byte keeps the checksum algorithm self-describing
# per record so mixed-provenance containers stay verifiable.
RECORD_SYNC = b"\xf9NLZREC\xa5"
_V2_HDR = struct.Struct("<BQI")
_V2_PREFIX = len(RECORD_SYNC) + _V2_HDR.size

#: name -> flag byte.  ``crc32`` is zlib's C implementation — always
#: available, fast enough that checksummed writes stay within the ≤5%
#: container-overhead budget.  ``crc32c`` (the Castagnoli polynomial used by
#: ext4/gcs) is honored when the optional ``crc32c`` wheel is importable;
#: it is never auto-selected, so archives stay verifiable on every machine.
CHECKSUM_ALGOS = {"crc32": 0, "crc32c": 1}

try:  # optional wheel; flag byte 1 in record headers
    import crc32c as _crc32c_mod
except ImportError:  # pragma: no cover - depends on environment
    _crc32c_mod = None

_DURABILITY_LEVELS = ("none", "flush", "fsync")


class CorruptArchiveError(ValueError):
    """A streaming container (or one record in it) failed validation.

    Carries ``offset`` (byte position of the bad record, when known) and
    ``path`` so callers can pinpoint damage; raised instead of bare
    ``struct.error``/msgpack exceptions on truncated or garbage input.
    """

    def __init__(self, message: str, *, offset: int | None = None,
                 path: str | None = None):
        ctx = []
        if path is not None:
            ctx.append(f"path={path!r}")
        if offset is not None:
            ctx.append(f"offset={offset}")
        super().__init__(message + (f" [{', '.join(ctx)}]" if ctx else ""))
        self.offset = offset
        self.path = path


def _checksum(algo: int, data: bytes) -> int:
    if algo == 0:
        return zlib.crc32(data) & 0xFFFFFFFF
    if algo == 1:
        if _crc32c_mod is None:
            raise RuntimeError(
                "archive record uses crc32c checksums but the optional "
                "'crc32c' wheel is not installed")
        return _crc32c_mod.crc32c(data) & 0xFFFFFFFF
    raise CorruptArchiveError(f"unknown checksum algorithm flag {algo}")


def is_streaming_archive(path_or_bytes) -> bool:
    """Sniff the streaming-container magic (path or leading bytes).

    False — never an exception — for short/garbage input, including files
    under 8 bytes.
    """
    if isinstance(path_or_bytes, (bytes, bytearray)):
        head = bytes(path_or_bytes[:8])
    else:
        try:
            with open(path_or_bytes, "rb") as f:
                head = f.read(8)
        except (OSError, TypeError, ValueError):
            return False
    return head in _MAGICS


def _read_record_at(f, offset: int, version: int, *, path=None,
                    verify_checksum: bool = True):
    """Read + decode one record at ``offset``; returns
    ``(obj, payload_len, next_offset)``.  All failure modes — truncation,
    bad sync, checksum mismatch, undecodable msgpack — raise
    :class:`CorruptArchiveError` with offset context."""
    f.seek(offset)
    if version == 1:
        hdr = f.read(_LEN.size)
        if len(hdr) < _LEN.size:
            raise CorruptArchiveError("truncated record header",
                                      offset=offset, path=path)
        (n,) = _LEN.unpack(hdr)
        body_off = offset + _LEN.size
    else:
        pre = f.read(_V2_PREFIX)
        if len(pre) < _V2_PREFIX:
            raise CorruptArchiveError("truncated record header",
                                      offset=offset, path=path)
        if pre[:len(RECORD_SYNC)] != RECORD_SYNC:
            raise CorruptArchiveError("missing record sync marker",
                                      offset=offset, path=path)
        algo, n, crc = _V2_HDR.unpack(pre[len(RECORD_SYNC):])
        body_off = offset + _V2_PREFIX
    payload = f.read(n)
    if len(payload) < n:
        raise CorruptArchiveError(
            f"truncated record payload ({len(payload)}/{n} bytes)",
            offset=offset, path=path)
    if version == 2 and verify_checksum and _checksum(algo, payload) != crc:
        raise CorruptArchiveError("record checksum mismatch",
                                  offset=offset, path=path)
    try:
        obj = loads(payload)
    except Exception as e:
        raise CorruptArchiveError(f"undecodable record: {e}",
                                  offset=offset, path=path) from e
    return obj, n, body_off + n


def _find_sync(f, start: int, end: int, chunk: int = 1 << 16):
    """Next RECORD_SYNC occurrence at/after ``start`` (chunked scan with
    marker-straddling overlap), or None."""
    overlap = len(RECORD_SYNC) - 1
    pos = start
    while pos < end:
        f.seek(pos)
        buf = f.read(min(chunk + overlap, end - pos))
        i = buf.find(RECORD_SYNC)
        if i >= 0:
            return pos + i
        if len(buf) <= overlap:
            return None
        pos += len(buf) - overlap
    return None


class ArchiveAppender:
    """Incremental streaming-archive writer.

    ``append``/``add_entry`` write self-delimiting msgpack records as they
    arrive (the async writer thread calls this one entry at a time);
    ``finalize`` seals the container with the index footer.  ``sink`` is a
    path or a binary file object.

    ``version=2`` (default) writes the durable ``NLZSTRM2`` format:
    per-record sync markers + checksums, an optional ``prelude`` metadata
    record crash-readable before any entry lands, and a ``durability``
    policy — ``"none"`` (buffered), ``"flush"`` (per-entry flush) or
    ``"fsync"`` (per-entry flush + fsync, so a sealed entry survives OS
    crash, not just process death).  ``version=1`` reproduces the legacy
    ``NLZSTRM1`` byte stream exactly.
    """

    def __init__(self, sink, *, version: int = 2, durability: str = "none",
                 checksum: str = "crc32", prelude: dict | None = None):
        if version not in (1, 2):
            raise ValueError(f"unknown container version {version!r}")
        if durability not in _DURABILITY_LEVELS:
            raise ValueError(f"durability must be one of {_DURABILITY_LEVELS},"
                             f" got {durability!r}")
        if checksum not in CHECKSUM_ALGOS:
            raise ValueError(f"checksum must be one of "
                             f"{tuple(CHECKSUM_ALGOS)}, got {checksum!r}")
        self.version = version
        self.durability = durability
        self._algo = CHECKSUM_ALGOS[checksum]
        self._magic = STREAM_MAGIC if version == 1 else STREAM_MAGIC_V2
        self._own = isinstance(sink, (str, bytes, os.PathLike))
        self._f = open(sink, "wb") if self._own else sink
        self._f.write(self._magic)
        self._offset = len(self._magic)
        self.entries: dict[str, list[int]] = {}   # name -> [offset, length]
        self.bytes_written = self._offset
        if prelude is not None:
            if version == 1:
                raise ValueError("prelude records require container version 2")
            self.append({"prelude": version, "meta": prelude})
            self._sync()

    def append(self, obj) -> tuple[int, int]:
        data = dumps(obj)
        off = self._offset
        if self.version == 1:
            self._f.write(_LEN.pack(len(data)))
            self._f.write(data)
            self._offset += _LEN.size + len(data)
        else:
            crc = _checksum(self._algo, data)
            self._f.write(RECORD_SYNC)
            self._f.write(_V2_HDR.pack(self._algo, len(data), crc))
            self._f.write(data)
            self._offset += _V2_PREFIX + len(data)
        self.bytes_written = self._offset
        return off, len(data)

    def add_entry(self, name: str, entry: dict) -> None:
        off, ln = self.append({"name": name, "entry": entry})
        self.entries[name] = [off, ln]
        self._sync()

    def _sync(self) -> None:
        if self.durability == "none":
            return
        self._f.flush()
        if self.durability == "fsync":
            try:
                os.fsync(self._f.fileno())
            except (OSError, AttributeError, io.UnsupportedOperation):
                pass  # in-memory sinks (BytesIO) have nothing to fsync

    def finalize(self, meta: dict) -> int:
        """Write the index footer; returns total container bytes."""
        footer = {"version": self.version, "meta": meta,
                  "entries": self.entries}
        foff, _ = self.append(footer)
        self._f.write(_LEN.pack(foff))
        self._f.write(self._magic)
        self._offset += _LEN.size + len(self._magic)
        self.bytes_written = self._offset
        self._f.flush()
        if self.durability == "fsync":
            self._sync()
        if self._own:
            self._f.close()
        return self._offset

    def rewind(self, offset: int) -> None:
        """Roll the container back to ``offset`` (a record boundary): the
        writer's retry path drops a partially-written record before
        re-attempting it, so a retried entry never leaves torn bytes."""
        self._f.seek(offset)
        try:
            self._f.truncate(offset)
        except (OSError, io.UnsupportedOperation):
            pass  # non-truncatable sink: the retried record overwrites
        self._offset = offset
        self.bytes_written = offset
        self.entries = {n: v for n, v in self.entries.items()
                        if v[0] < offset}

    def abort(self) -> None:
        """Close without a footer (error path); the file stays sniffable as
        a streaming archive but footer-less — by design, half-written
        snapshots must not decode silently.  On v2 the sealed entries are
        still recoverable via ``repair=True``."""
        self._f.flush()
        if self._own:
            self._f.close()


def scan_container(source, *, path: str | None = None) -> dict:
    """Salvage scan: walk a streaming container record by record from the
    front, independent of the footer.

    Works on sealed, footerless and truncated containers.  Returns::

        {"version", "sealed", "entries": {name: [off, len]}, "meta",
         "prelude", "footer_offset", "damage": [{"offset", "error"}, ...]}

    Every checksum-intact entry record is indexed; damaged stretches are
    reported and — on v2 — skipped by resynchronizing on the record sync
    marker (v1 has no sync markers, so a v1 scan stops at the first bad
    record).  ``meta`` comes from the footer when the walk reaches one,
    else from the prelude, else ``{}``.
    """
    own = isinstance(source, (str, bytes, os.PathLike))
    if own and path is None:
        path = os.fspath(source)
    f = open(source, "rb") if own else source
    try:
        end = f.seek(0, io.SEEK_END)
        f.seek(0)
        head = f.read(8)
        if head not in _MAGICS:
            raise CorruptArchiveError(
                "not a NeurLZ streaming archive (bad magic)", path=path)
        version = 1 if head == STREAM_MAGIC else 2
        out = {"version": version, "sealed": False, "entries": {},
               "meta": None, "prelude": None, "footer_offset": None,
               "damage": []}
        footer_meta = None
        off = len(head)
        trailer_len = _LEN.size + len(head)
        while off < end:
            if end - off == trailer_len:
                f.seek(off)
                tail = f.read(trailer_len)
                if tail[_LEN.size:] == head:
                    out["sealed"] = True
                    out["footer_offset"] = _LEN.unpack(tail[:_LEN.size])[0]
                    break
            try:
                rec, pln, nxt = _read_record_at(f, off, version, path=path)
            except CorruptArchiveError as e:
                out["damage"].append({"offset": off, "error": str(e)})
                if version == 1:
                    break
                resync = _find_sync(f, off + 1, end)
                if resync is None:
                    break
                off = resync
                continue
            if isinstance(rec, dict) and "name" in rec and "entry" in rec:
                out["entries"][rec["name"]] = [off, pln]
            elif isinstance(rec, dict) and rec.get("prelude"):
                out["prelude"] = rec.get("meta")
            elif isinstance(rec, dict) and "entries" in rec and "meta" in rec:
                footer_meta = rec["meta"]
            off = nxt
        if footer_meta is not None:
            out["meta"] = footer_meta
        elif out["prelude"] is not None:
            out["meta"] = out["prelude"]
        else:
            out["meta"] = {}
        return out
    finally:
        if own:
            f.close()


class ArchiveReader:
    """Random-access reader for the streaming container (v1 and v2).

    Decodes the index footer once, then ``read_entry(name)`` loads exactly
    one field's record from disk — the basis of one-field-at-a-time decode.
    On v2 every record read is checksum-verified.  ``entry_reads`` records
    every entry record pulled off disk, in order (the footer is not an
    entry) — the accounting that lets tests assert a lazy decode touched
    only one field's aux closure.

    ``repair=True`` skips the footer entirely and rebuilds the index with
    :func:`scan_container` — the path for footerless/truncated (crashed)
    containers; ``salvaged`` is True when the container was not sealed.
    """

    def __init__(self, source, *, repair: bool = False):
        self._own = isinstance(source, (str, bytes, os.PathLike))
        self._path = os.fspath(source) if self._own else None
        self._f = open(source, "rb") if self._own else source
        self._f.seek(0)
        head = self._f.read(8)
        if head not in _MAGICS:
            raise CorruptArchiveError(
                "not a NeurLZ streaming archive (bad magic)", path=self._path)
        self.version = 1 if head == STREAM_MAGIC else 2
        self._magic = head
        self.salvaged = False
        self.prelude: dict | None = None
        self.damage: list[dict] = []
        if repair:
            self._load_salvaged()
        else:
            self._load_footer()
        self.entry_reads: list[str] = []

    def _load_footer(self) -> None:
        end = self._f.seek(0, io.SEEK_END)
        trailer_len = _LEN.size + len(self._magic)
        if end < len(self._magic) + trailer_len:
            raise CorruptArchiveError(
                "container too short for a trailer (crashed write? open "
                "with repair=True to salvage)", offset=end, path=self._path)
        self._f.seek(end - trailer_len)
        foff = _LEN.unpack(self._f.read(_LEN.size))[0]
        if self._f.read(len(self._magic)) != self._magic:
            raise CorruptArchiveError(
                "truncated streaming archive (no trailer; open with "
                "repair=True to salvage)", path=self._path)
        if not len(self._magic) <= foff < end - trailer_len:
            raise CorruptArchiveError(
                "footer offset out of range", offset=foff, path=self._path)
        footer = self._read_record(foff)
        if not (isinstance(footer, dict) and "entries" in footer
                and "meta" in footer):
            raise CorruptArchiveError(
                "trailer does not point at an index footer", offset=foff,
                path=self._path)
        self.version = footer.get("version", self.version)
        self.meta = footer["meta"]
        self.entries = footer["entries"]

    def _load_salvaged(self) -> None:
        scan = scan_container(self._f, path=self._path)
        self.meta = scan["meta"]
        self.entries = scan["entries"]
        self.prelude = scan["prelude"]
        self.damage = scan["damage"]
        self.salvaged = not scan["sealed"]

    def read_prelude(self) -> dict | None:
        """The v2 prelude metadata record, or None (v1, or none written)."""
        if self.prelude is not None or self.version != 2:
            return self.prelude
        try:
            rec, _, _ = _read_record_at(self._f, len(self._magic), 2,
                                        path=self._path)
        except CorruptArchiveError:
            return None
        if isinstance(rec, dict) and rec.get("prelude"):
            self.prelude = rec.get("meta")
        return self.prelude

    def _read_record(self, offset: int):
        obj, _, _ = _read_record_at(self._f, offset, self.version,
                                    path=self._path)
        return obj

    def read_entry(self, name: str) -> dict:
        off, _ = self.entries[name]
        rec = self._read_record(off)
        if not (isinstance(rec, dict) and "name" in rec and "entry" in rec):
            raise CorruptArchiveError(
                f"index for {name!r} does not point at an entry record",
                offset=off, path=self._path)
        if rec["name"] != name:
            raise CorruptArchiveError(
                f"index points at {rec['name']!r}, not {name!r}",
                offset=off, path=self._path)
        self.entry_reads.append(name)
        return rec["entry"]

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def verify_container(source) -> dict:
    """Entry-by-entry integrity check.

    Returns ``{"version", "sealed", "ok", "entries": {name: {"offset",
    "ok", "error"}}}``.  On a sealed container every indexed entry is
    re-read through the checksum-verified path (v2) or decode-validated
    (v1), so a flipped bit is pinpointed by entry name and offset.  On an
    unsealed (crashed) container the salvage index is verified instead and
    ``sealed``/``ok`` are False.
    """
    own = isinstance(source, (str, bytes, os.PathLike))
    path = os.fspath(source) if own else None
    f = open(source, "rb") if own else source
    try:
        try:
            reader = ArchiveReader(f)
            sealed = True
        except CorruptArchiveError:
            f.seek(0)
            reader = ArchiveReader(f, repair=True)
            sealed = not reader.salvaged
        report = {"version": reader.version, "sealed": sealed,
                  "entries": {}, "ok": False}
        for name, (off, _ln) in reader.entries.items():
            status = {"offset": off, "ok": True, "error": None}
            try:
                rec = reader._read_record(off)
                got = rec.get("name") if isinstance(rec, dict) else None
                if got != name:
                    raise CorruptArchiveError(
                        f"index points at {got!r}, not {name!r}",
                        offset=off, path=path)
            except CorruptArchiveError as e:
                status["ok"] = False
                status["error"] = str(e)
            report["entries"][name] = status
        report["ok"] = sealed and all(
            s["ok"] for s in report["entries"].values())
        return report
    finally:
        if own:
            f.close()


def pack_weights(params_tree, dtype: str = "float32") -> dict:
    """Flatten an enhancer param tree into one compressed blob (archive
    payload).  The codec name rides in the header so a zlib-only decoder can
    read archives written with zstd and vice versa."""
    import jax

    leaves, treedef = jax.tree.flatten(params_tree)
    arrs = [np.asarray(l, dtype=dtype) for l in leaves]
    buf = io.BytesIO()
    for a in arrs:
        buf.write(a.tobytes())
    payload, cname = codec.compress(buf.getvalue(), 9)
    return {
        "dtype": dtype,
        "shapes": [list(a.shape) for a in arrs],
        "payload": payload,
        "codec": cname,
        "nbytes": len(payload),
        "raw_nbytes": sum(a.nbytes for a in arrs),
        "n_params": sum(a.size for a in arrs),
    }


def unpack_weights(blob: dict, params_like) -> object:
    """Inverse of :func:`pack_weights`, restored into ``params_like`` tree."""
    import jax
    import jax.numpy as jnp

    raw = codec.decompress(blob["payload"], blob.get("codec", "zstd"))
    leaves, treedef = jax.tree.flatten(params_like)
    out, off = [], 0
    dt = np.dtype(blob["dtype"])
    for leaf, shape in zip(leaves, blob["shapes"]):
        n = int(np.prod(shape)) * dt.itemsize
        arr = np.frombuffer(raw[off:off + n], dtype=dt).reshape(shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
