"""The paper's lightweight *skipping DNN* enhancer (§3.2.2, Fig. 8).

Ten conv layers — four stride-2 down-samplings, four stride-2 up-samplings
with skip-connection concatenations, plus input/output convs — totalling
~3,073 parameters at ``c_in=1`` (the paper reports "a 10-layer network
requires only 3,000 parameters").  Pure-JAX pytree params; the forward pass
is `jit`/`vmap`/`shard_map`-friendly so thousands of per-block enhancers can
train simultaneously across a pod (DESIGN.md §3, batched block training).

Output heads (§3.3.2, Fig. 6):
  * ``regulated``   — Sigmoid squashed to ``(2σ(z)−1) ∈ (−1, 1)``; since the
    residual target is normalized by the error bound, the enhanced value can
    exactly reach the original (balanced regulation, Case B) while the total
    error stays ≤ 2×eb.
  * ``skip=False`` gives the non-skipping ablation of Fig. 4 (same depth).

Forward formulation (the bit-stable fast path)
----------------------------------------------
These convs are XLA's worst case: 3×3 kernels over 1–16 channels lower to
``conv_general_dilated`` programs that run ~3 GFLOP/s on CPU.  The forward
here instead expresses every conv as an accumulation of nine shifted
``jax.lax.dot_general`` contractions (one GEMM per kernel tap) and every
stride-2 transpose conv as its sub-pixel decomposition — four parity planes,
each a small accumulation of taps on the un-dilated grid, interleaved back.
All contractions are pinned to ``precision=HIGHEST``, additions happen in a
fixed tap order, and single-output-channel convs are padded to two columns
(a ``(K, 1)`` GEMV re-associates under ``vmap`` where a ``(K, 2)`` GEMM does
not), which makes the forward **byte-identical** under eager, ``jit``,
``vmap``-over-fields and grad — the property the batched engine's stacked
strategy and the conv-stage jit path rely on (tests/test_lowering.py).
It is also 2–3× faster than the XLA conv lowering on CPU (bench_kernels
``kernel/dnn_forward`` row).

The historical XLA formulation is kept as :func:`forward_reference` — the
accuracy oracle and the perf baseline; it is *not* bit-identical to
:func:`forward` (different contraction order), which is why PR 9 swapped the
formulation for every path at once instead of dispatching between them.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch

_DN = ("NHWC", "HWIO", "NHWC")
_P = jax.lax.Precision.HIGHEST


@dataclasses.dataclass(frozen=True)
class SkippingDNNConfig:
    c_in: int = 1                 # 1 = single-field, >1 = cross-field channels
    widths: tuple = (4, 4, 6, 6, 8)   # conv_in + four encoder stages
    regulated: bool = True
    skip: bool = True
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def _conv_param(key, kh, kw, cin, cout, dtype):
    wkey, _ = jax.random.split(key)
    fan_in = kh * kw * cin
    # Note: float(...) keeps the He scale weakly typed (x64 mode would
    # otherwise promote the whole kernel to float64).
    w = jax.random.normal(wkey, (kh, kw, cin, cout), dtype) * float(np.sqrt(2.0 / fan_in))
    return {"w": w.astype(dtype), "b": jnp.zeros((cout,), dtype)}


def init_params(key, cfg: SkippingDNNConfig):
    c0, c1, c2, c3, c4 = cfg.widths
    dt = cfg.jdtype
    keys = jax.random.split(key, 10)
    if cfg.skip:
        up_in = (c4, c3 + c3, c2 + c2, c1 + c1)  # after concat with encoder feature
        out_in = c1 + c0
    else:
        up_in = (c4, c3, c2, c1)
        out_in = c1
    return {
        "conv_in": _conv_param(keys[0], 3, 3, cfg.c_in, c0, dt),
        "down1": _conv_param(keys[1], 3, 3, c0, c1, dt),
        "down2": _conv_param(keys[2], 3, 3, c1, c2, dt),
        "down3": _conv_param(keys[3], 3, 3, c2, c3, dt),
        "down4": _conv_param(keys[4], 3, 3, c3, c4, dt),
        "up1": _conv_param(keys[5], 3, 3, up_in[0], c3, dt),
        "up2": _conv_param(keys[6], 3, 3, up_in[1], c2, dt),
        "up3": _conv_param(keys[7], 3, 3, up_in[2], c1, dt),
        "up4": _conv_param(keys[8], 3, 3, up_in[3], c1, dt),
        "conv_out": _conv_param(keys[9], 3, 3, out_in, 1, dt),
    }


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def stack_params(params_list):
    """Stack F same-structure enhancer trees into one tree with a leading
    field axis — the layout the batched engine trains under ``jax.vmap`` and
    shards across devices (``repro.distributed.sharding.field_sharding``)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *params_list)


def unstack_params(stacked, num_fields: int):
    """Inverse of :func:`stack_params`: per-field trees (views, no copy)."""
    return [jax.tree.map(lambda x, i=i: x[i], stacked)
            for i in range(num_fields)]


# ---------------------------------------------------------------------------
# Fast bit-stable formulation: convs as accumulated shifted GEMMs
# ---------------------------------------------------------------------------

def _dot(a, w):
    """Contract ``a``'s channel axis with ``w [cin, cout]``; one GEMM,
    precision pinned so the reduction is never FMA-contracted or split."""
    return jax.lax.dot_general(a, w, (((a.ndim - 1,), (0,)), ((), ())),
                               precision=_P)


def _conv_taps(x, w, b, stride):
    """SAME 3×3 conv as nine shifted ``_dot`` accumulations, fixed tap order."""
    n, h, wd, cin = x.shape
    ho = (h + stride - 1) // stride
    wo = (wd + stride - 1) // stride

    def pads(size, out):
        total = max((out - 1) * stride + 3 - size, 0)
        lo = total // 2
        return lo, total - lo

    ylo, yhi = pads(h, ho)
    xlo, xhi = pads(wd, wo)
    xp = jnp.pad(x, ((0, 0), (ylo, yhi), (xlo, xhi), (0, 0)))
    acc = None
    for dy in range(3):
        for dx in range(3):
            win = jax.lax.slice(
                xp, (0, dy, dx, 0),
                (n, dy + (ho - 1) * stride + 1, dx + (wo - 1) * stride + 1,
                 cin),
                (1, stride, stride, 1))
            t = _dot(win, w[dy, dx])
            acc = t if acc is None else acc + t
    return acc + b


def _conv(x, p, stride=1):
    w, b = p["w"], p["b"]
    cout = w.shape[-1]
    if cout == 1:
        # A (K, 1) contraction lowers to a GEMV whose batched form under
        # vmap re-associates the reduction; a zero-padded (K, 2) GEMM lowers
        # identically in both — the sole source of vmap bit-divergence.
        w = jnp.concatenate([w, jnp.zeros_like(w)], axis=-1)
        b = jnp.concatenate([b, jnp.zeros_like(b)])
        return _conv_taps(x, w, b, stride)[..., :1]
    return _conv_taps(x, w, b, stride)


def _deconv(x, p):
    """Stride-2 SAME 3×3 transpose conv via sub-pixel decomposition.

    ``conv_transpose(k=3, s=2, SAME)`` ≡ zero-dilate + pad (2, 1) + VALID
    conv with the unflipped kernel, so output row ``2i+py`` only sees input
    rows through kernel taps ``dy ∈ {py, py+2} ∩ [0, 2]`` — each parity
    plane is a tiny accumulation on the *small* grid, interleaved back.
    """
    w, b = p["w"], p["b"]
    n, h, wd, cin = x.shape
    cout = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    out = jnp.zeros((n, 2 * h, 2 * wd, cout), x.dtype)
    for py in range(2):
        ytaps = [(py, py - 1)] + ([(py + 2, py)] if py + 2 <= 2 else [])
        for px in range(2):
            xtaps = [(px, px - 1)] + ([(px + 2, px)] if px + 2 <= 2 else [])
            acc = None
            for dy, my in ytaps:
                for dx, mx in xtaps:
                    win = jax.lax.slice(xp, (0, my + 1, mx + 1, 0),
                                        (n, my + 1 + h, mx + 1 + wd, cin))
                    t = _dot(win, w[dy, dx])
                    acc = t if acc is None else acc + t
            out = out.at[:, py::2, px::2, :].set(acc)
    return out + b


# ---------------------------------------------------------------------------
# Historical XLA formulation — accuracy oracle + perf baseline
# ---------------------------------------------------------------------------

def _conv_xla(x, p, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=_DN)
    return y + p["b"]


def _deconv_xla(x, p):
    y = jax.lax.conv_transpose(
        x, p["w"], strides=(2, 2), padding="SAME", dimension_numbers=_DN)
    return y + p["b"]


def _forward_core(params, x, *, regulated, skip, conv, deconv):
    """x: [N, H, W, C_in] normalized decompressed slices -> [N, H, W, 1]
    normalized residual prediction.  H, W are padded to multiples of 16
    internally (replicate edges) and cropped back."""
    n, h, w, _ = x.shape
    ph, pw = (-h) % 16, (-w) % 16
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (0, ph), (0, pw), (0, 0)), mode="edge")

    act = jax.nn.relu
    f0 = act(conv(x, params["conv_in"]))          # H
    f1 = act(conv(f0, params["down1"], stride=2))  # H/2
    f2 = act(conv(f1, params["down2"], stride=2))  # H/4
    f3 = act(conv(f2, params["down3"], stride=2))  # H/8
    f4 = act(conv(f3, params["down4"], stride=2))  # H/16

    u = act(deconv(f4, params["up1"]))             # H/8
    if skip:
        u = jnp.concatenate([u, f3], axis=-1)
    u = act(deconv(u, params["up2"]))              # H/4
    if skip:
        u = jnp.concatenate([u, f2], axis=-1)
    u = act(deconv(u, params["up3"]))              # H/2
    if skip:
        u = jnp.concatenate([u, f1], axis=-1)
    u = act(deconv(u, params["up4"]))              # H
    if skip:
        u = jnp.concatenate([u, f0], axis=-1)
    z = conv(u, params["conv_out"])                # [N,H,W,1]

    if regulated:
        out = 2.0 * jax.nn.sigmoid(z) - 1.0        # (−1, 1): balanced 2×eb regulation
    else:
        out = z
    if ph or pw:
        out = out[:, :h, :w, :]
    return out


@partial(jax.jit, static_argnames=("regulated", "skip"))
def _forward_fast(params, x, *, regulated: bool = True, skip: bool = True):
    return _forward_core(params, x, regulated=regulated, skip=skip,
                         conv=_conv, deconv=_deconv)


@partial(jax.jit, static_argnames=("regulated", "skip"))
def forward_reference(params, x, *, regulated: bool = True,
                      skip: bool = True):
    """The pre-PR9 XLA-conv forward.  Numerically ~1e-6-close to
    :func:`forward` but NOT bit-identical; kept as the accuracy oracle and
    the ``kernel/dnn_forward`` bench baseline."""
    return _forward_core(params, x, regulated=regulated, skip=skip,
                         conv=_conv_xla, deconv=_deconv_xla)


def _forward_pallas(params, x, *, regulated: bool = True, skip: bool = True):
    """Conv layers through the ``conv2d3x3`` Pallas kernel (TPU target);
    transpose convs stay on the sub-pixel formulation.  Only engaged when
    the parity probe proves it byte-identical to :func:`_forward_fast` on
    this backend."""
    from ..kernels import ops as kernel_ops

    def conv(xx, p, stride=1):
        return kernel_ops.conv3x3(xx, p["w"], p["b"], stride=stride,
                                  relu=False)

    return _forward_core(params, x, regulated=regulated, skip=skip,
                         conv=conv, deconv=_deconv)


def _pallas_probe() -> bool:
    cfg = SkippingDNNConfig(c_in=1)
    params = init_params(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 17, 13, 1), jnp.float32)
    want = np.asarray(_forward_fast(params, x, regulated=True, skip=True))
    got = np.asarray(_forward_pallas(params, x, regulated=True, skip=True))
    return want.tobytes() == got.tobytes()


def forward(params, x, *, regulated: bool = True, skip: bool = True,
            lowering: str = "auto"):
    """Skipping-DNN forward under the requested lowering.

    ``eager`` and ``jit`` are the *same* compiled bit-stable fast
    formulation (it is jit-safe by construction — HIGHEST-precision GEMMs
    in a fixed accumulation order leave XLA nothing to contract), so the
    eager/jit byte-identity half of the contract holds structurally;
    ``pallas`` routes the convs through the hand-written kernel where the
    parity probe passes (TPU), falling back here otherwise.  Traceable:
    resolution happens at trace time, so callers may close over a fixed
    ``lowering`` inside their own jit/scan.
    """
    if lowering in ("eager", "jit"):
        return _forward_fast(params, x, regulated=regulated, skip=skip)
    impl, _ = dispatch.resolve("dnn_forward", lowering)
    return impl(params, x, regulated=regulated, skip=skip)


dispatch.register("dnn_forward", "eager", _forward_fast)
dispatch.register("dnn_forward", "jit", _forward_fast)
dispatch.register("dnn_forward", "pallas", _forward_pallas,
                  probe=_pallas_probe, backends=("tpu",))


def apply(params, x, cfg: SkippingDNNConfig):
    return forward(params, x, regulated=cfg.regulated, skip=cfg.skip)
