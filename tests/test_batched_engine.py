"""Batched multi-field engine: equivalence with the serial reference,
ragged shapes, archive codec round-trips (zstd and zlib fallback)."""
import numpy as np
import pytest

from repro import core
from repro.compressors import codec
from repro.core import archive as A
from repro.data import fields as F

FIELDS = F.make_fields("nyx", shape=(8, 16, 16), seed=7)
NAMES = list(FIELDS)


def _cfg(engine="serial", **kw):
    return core.NeurLZConfig(epochs=2, mode="strict", engine=engine, **kw)


def _fields_dump(arc):
    return A.dumps(arc["fields"])


def test_batched_matches_serial_bitwise():
    """Same config/seed -> identical archives and reconstructions."""
    arc_s = core.compress(FIELDS, rel_eb=1e-3, config=_cfg())
    arc_b = core.compress(FIELDS, rel_eb=1e-3, config=_cfg("batched"))
    assert _fields_dump(arc_s) == _fields_dump(arc_b)
    dec_s = core.decompress(arc_s, engine="serial")
    dec_b = core.decompress(arc_b, engine="batched")
    for name in FIELDS:
        assert np.array_equal(dec_s[name], dec_b[name])


def test_batched_group_size_does_not_change_results():
    ref = None
    for gs in (0, 1, 3):
        arc = core.compress(FIELDS, rel_eb=1e-3,
                            config=_cfg("batched", group_size=gs))
        dump = _fields_dump(arc)
        assert ref is None or dump == ref
        ref = dump


def test_batched_ragged_slice_counts():
    """Fields with differing slice counts share one group; the unroll path
    stays bit-identical to serial even when ragged."""
    rag = {"a": FIELDS[NAMES[0]], "b": FIELDS[NAMES[1]][:5]}
    arc_s = core.compress(rag, rel_eb=1e-3, config=_cfg())
    arc_b = core.compress(rag, rel_eb=1e-3, config=_cfg("batched"))
    assert _fields_dump(arc_s) == _fields_dump(arc_b)
    dec = core.decompress(arc_b, engine="batched")
    for name, x in rag.items():
        eb = arc_b["fields"][name]["abs_eb"]
        err = np.abs(dec[name].astype(np.float64)
                     - x.astype(np.float64)).max()
        assert err <= eb


def test_batched_cross_field():
    cross = {NAMES[0]: (NAMES[1],)}
    arc_s = core.compress(FIELDS, rel_eb=1e-3,
                          config=_cfg(cross_field=cross))
    arc_b = core.compress(FIELDS, rel_eb=1e-3,
                          config=_cfg("batched", cross_field=cross))
    assert arc_b["fields"][NAMES[0]]["net"]["c_in"] == 2
    assert _fields_dump(arc_s) == _fields_dump(arc_b)


def test_vmap_strategy_respects_strict_bound():
    """The stacked-vmap strategy trades bit-equality for batching, but the
    strict 1x error bound must still hold exactly."""
    arc = core.compress(FIELDS, rel_eb=1e-3,
                        config=_cfg("batched", field_batching="vmap"))
    dec = core.decompress(arc, engine="batched")
    for name, x in FIELDS.items():
        eb = arc["fields"][name]["abs_eb"]
        err = np.abs(dec[name].astype(np.float64)
                     - x.astype(np.float64)).max()
        assert err <= eb


def test_unknown_engine_and_strategy_rejected():
    with pytest.raises(ValueError):
        core.compress(FIELDS, rel_eb=1e-3,
                      config=core.NeurLZConfig(engine="warp"))
    with pytest.raises(ValueError):
        core.compress(FIELDS, rel_eb=1e-3,
                      config=_cfg("batched", field_batching="teleport"))


# ---------------------------------------------------------------------------
# Archive codec round-trips (zstd optional, zlib fallback)
# ---------------------------------------------------------------------------

@pytest.fixture
def force_codec():
    def _force(name):
        codec.set_default_codec(name)
    yield _force
    codec.set_default_codec(None)


@pytest.mark.parametrize("name", ["zlib", "zstd"])
def test_archive_roundtrip_under_codec(tmp_path, force_codec, name):
    if name == "zstd" and not codec.HAVE_ZSTD:
        pytest.skip("zstandard not installed")
    force_codec(name)
    sub = {NAMES[0]: FIELDS[NAMES[0]]}
    arc = core.compress(sub, rel_eb=1e-3, config=_cfg("batched"))
    assert arc["fields"][NAMES[0]]["weights"]["codec"] == name
    path = str(tmp_path / "block.nlz")
    core.save(path, arc)
    dec = core.decompress(core.load(path))
    ref = core.decompress(arc)
    assert np.array_equal(dec[NAMES[0]], ref[NAMES[0]])


def test_zlib_archive_decodes_without_forced_codec(force_codec):
    """Codec name travels in the header: a zlib archive decodes even when
    the process default would pick zstd."""
    force_codec("zlib")
    sub = {NAMES[0]: FIELDS[NAMES[0]]}
    arc = core.compress(sub, rel_eb=1e-3, config=_cfg())
    blob = A.loads(A.dumps(arc))
    codec.set_default_codec(None)
    dec = core.decompress(blob)
    eb = arc["fields"][NAMES[0]]["abs_eb"]
    err = np.abs(dec[NAMES[0]].astype(np.float64)
                 - FIELDS[NAMES[0]].astype(np.float64)).max()
    assert err <= eb


def test_codec_sniffing_roundtrip(force_codec):
    """Headerless streams (checkpoints) decode by magic sniffing."""
    payload = b"neurlz" * 100
    for name in codec.available_codecs():
        force_codec(name)
        comp, used = codec.compress(payload)
        assert used == name
        assert codec.decompress_sniffed(comp) == payload


def test_zstd_unavailable_raises_helpfully():
    if codec.HAVE_ZSTD:
        pytest.skip("zstandard installed")
    with pytest.raises(ImportError):
        codec.compress(b"x", codec="zstd")
