"""Streaming pipeline rows: resident-bytes ceiling vs the in-memory engine,
wall-clock delta, and writer overlap.

Each row compresses the same multi-field snapshot twice: with the in-memory
batched engine (everything resident, end-of-run archive assembly) and
through ``repro.streaming`` under a ``max_resident_bytes`` budget smaller
than the snapshot's total field bytes.  Reported per row:

* ``peak_resident`` — the pipeline's residency-ledger peak (must stay under
  ``budget``; the ledger tracks originals, conventional reconstructions and
  training tensors),
* ``total_field_bytes`` — the snapshot size the budget is beaten against,
* ``inmem_s``/``stream_s``/``delta_pct`` — wall-clock cost of streaming,
* ``writer_overlap`` — fraction of entry packing + archival hidden behind
  training (1.0 = fully overlapped),
* ``bit_identical`` — streamed archive entries byte-equal the in-memory
  engine's (which is itself bit-equal to serial),
* ``peak_rss_mb`` — OS-level peak for context (process-lifetime, monotonic).
"""
from __future__ import annotations

import io
import time

from . import common
from repro import core, streaming
from repro.core import archive as arc_io


def _stream_rows(num_fields: int, shape, epochs: int, repeats: int = 1):
    flds = common.snapshot_fields(num_fields, shape=shape)
    total = sum(x.nbytes for x in flds.values())
    one = next(iter(flds.values()))
    # Working set of one single-field group: original + reconstruction +
    # inputs + targets.  The budget admits ~2.2 groups (enough for the
    # pipeline's steady state of current + prefetched) and sits well under
    # the snapshot's total field bytes — the out-of-core claim being
    # measured.
    budget = int(2.2 * 4 * one.nbytes)
    assert budget < total, "snapshot must exceed the residency budget"
    cfg_mem = core.NeurLZConfig(epochs=epochs, mode="strict",
                                engine="batched", group_size=1)
    cfg_st = core.NeurLZConfig(epochs=epochs, mode="strict",
                               engine="streaming", group_size=1,
                               max_resident_bytes=budget)
    t_mem, arc_mem = common.timed_compress(flds, 1e-3, cfg_mem, repeats)

    best, report, sink = float("inf"), None, None
    streaming.compress(flds, io.BytesIO(), 1e-3, config=cfg_st)  # jit warmup
    for _ in range(repeats):
        sink = io.BytesIO()
        t0 = time.time()
        rep = streaming.compress(flds, sink, 1e-3, config=cfg_st)
        dt = time.time() - t0
        if dt < best:
            best, report = dt, rep
    sink.seek(0)
    with arc_io.ArchiveReader(sink) as r:
        arc_st = core.assemble_streaming_archive(r)
    ident = int(arc_io.dumps(arc_mem["fields"])
                == arc_io.dumps(arc_st["fields"]))
    common.csv_row(
        f"streaming/fields{num_fields}/ep{epochs}",
        best * 1e6,
        f"budget={budget};peak_resident={report['peak_resident_bytes']};"
        f"under_budget={int(report['peak_resident_bytes'] <= budget)};"
        f"total_field_bytes={total};"
        f"inmem_s={t_mem:.3f};stream_s={best:.3f};"
        f"delta_pct={100.0 * (best - t_mem) / max(t_mem, 1e-9):.1f};"
        f"writer_overlap={common.writer_overlap(report):.2f};"
        f"bit_identical={ident};"
        f"peak_rss_mb={common.peak_rss_bytes() / 2**20:.0f}")


def run(full: bool = False, smoke: bool = False):
    if smoke:
        # CI regression profile: snapshot > budget, one epoch point.
        _stream_rows(10, (8, 16, 16), epochs=1, repeats=1)
        return
    _stream_rows(12, (16, 32, 32), epochs=3, repeats=2)
    if full:
        _stream_rows(16, (32, 64, 64), epochs=5, repeats=2)


if __name__ == "__main__":
    run()
