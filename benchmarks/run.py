"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/README of record:
EXPERIMENTS.md maps each prefix to the paper table/figure it reproduces).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "bench_error_validation",   # Fig 11 / Fig 5
    "bench_rate_psnr",          # Fig 10
    "bench_bitrate_reduction",  # Table 2
    "bench_scalability",        # Table 3
    "bench_ablations",          # Fig 4
    "bench_training_evolution", # Figs 7/12/16
    "bench_regulation",         # Fig 13 / §5.1
    "bench_conflict",           # Fig 17 / §5.3
    "bench_grad_compress",      # framework integration (DESIGN.md §4)
    "bench_kernels",            # Pallas kernel validation
    "bench_roofline",           # §Roofline table from dry-run records
    "bench_streaming",          # bounded-memory pipeline vs in-memory engine
]


# CI smoke subset: the kernel validations plus the engine-comparison rows of
# the scalability bench and the streaming-budget row, at tiny-field settings
# (see each module's smoke path).
MODULES_SMOKE = [
    "bench_kernels",
    "bench_scalability",
    "bench_streaming",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-field CI profile (fast, regression-only)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark module")
    args = ap.parse_args()

    failures = 0
    ran = 0
    modules = MODULES_SMOKE if args.smoke else MODULES
    for name in modules:
        if args.only and args.only not in name:
            continue
        ran += 1
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            import inspect
            kwargs = {"full": args.full}
            if "smoke" in inspect.signature(mod.run).parameters:
                kwargs["smoke"] = args.smoke
            mod.run(**kwargs)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  file=sys.stderr, flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if args.only and ran == 0:
        print(f"# --only {args.only!r} matched no module in "
              f"{modules}", file=sys.stderr)
        sys.exit(2)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
