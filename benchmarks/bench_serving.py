"""Serving-tier benchmarks (``repro.serve``): cold vs warm decode latency,
coalesced vs serial dispatch throughput, transcode wall-clock under a
residency budget.

The ``serving/coalesced_burst`` row doubles as a regression **guard**: a
burst of same-signature requests must execute in strictly fewer decode
dispatches than requests (the stacked ``decompress_batched`` path) — if
the server ever degrades to one dispatch per request, the run fails.
"""
from __future__ import annotations

import os
import tempfile
import time

from repro import core, streaming
from repro.serve import ArchiveServer, transcode
from repro.streaming.pipeline import ResidencyLedger

from . import common


def _build(path: str, fields, epochs: int):
    cfg = core.NeurLZConfig(engine="streaming", epochs=epochs)
    streaming.compress(fields, path, rel_eb=1e-3, config=cfg)
    return cfg


def run(full: bool = False, smoke: bool = False):
    shape = (8, 16, 16) if smoke else ((32, 48, 48) if full else (16, 32, 32))
    epochs = 2 if smoke else 5
    nfields = 4 if smoke else 6
    reps = 5 if smoke else 20
    fields = common.snapshot_fields(nfields, shape=shape)
    names = list(fields)
    tmp = tempfile.mkdtemp(prefix="bench-serving-")
    path = os.path.join(tmp, "snap.nlzs")
    _build(path, fields, epochs)

    # -- cold vs warm decode latency (the cache's reason to exist) ----------
    with ArchiveServer(path, max_bytes=1 << 30) as srv:
        t0 = time.perf_counter()
        srv.decode(names[0], timeout=600)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(reps):
            srv.decode(names[0], timeout=600)
        warm_s = (time.perf_counter() - t0) / reps
    common.csv_row("serving/decode_cold", cold_s * 1e6,
                   f"warm_us={warm_s * 1e6:.1f};"
                   f"warm_speedup={cold_s / max(warm_s, 1e-9):.1f}")

    # -- coalesced burst vs serial requests ---------------------------------
    srv = ArchiveServer(path, max_bytes=1 << 30, auto_start=False)
    futs = [srv.submit(n) for n in names]
    t0 = time.perf_counter()
    srv.start()
    for f in futs:
        f.result(600)
    coalesced_s = time.perf_counter() - t0
    stats = srv.decode_stats
    srv.close()
    if stats.dispatches >= len(names):
        raise RuntimeError(
            f"serving coalesce guard: {stats.dispatches} decode dispatches "
            f"for {len(names)} same-signature concurrent requests — the "
            "batching window degraded to per-request dispatch")

    # serial reference: same fields, one request per batch, cache disabled
    # (1-byte ceiling rejects every insertion) so each decode is cold
    with ArchiveServer(path, max_bytes=1, window_s=0.0) as srv2:
        t0 = time.perf_counter()
        for n in names:
            srv2.decode(n, timeout=600)
        serial_s = time.perf_counter() - t0
    common.csv_row(
        "serving/coalesced_burst", coalesced_s * 1e6 / len(names),
        f"serial_us_per_req={serial_s * 1e6 / len(names):.1f};"
        f"dispatches={stats.dispatches};requests={len(names)};"
        f"max_width={stats.max_width};"
        f"speedup={serial_s / max(coalesced_s, 1e-9):.2f}")

    # -- transcode wall-clock vs residency budget ---------------------------
    budget = 32 << 20
    ledger = ResidencyLedger(budget)
    dst = os.path.join(tmp, "requal.nlzs")
    cfg = core.NeurLZConfig(engine="streaming", epochs=epochs)
    t0 = time.perf_counter()
    out = transcode(path, dst, rel_eb=1e-2, config=cfg, ledger=ledger)
    wall_s = time.perf_counter() - t0
    peak = out.report["peak_resident_bytes"]
    out.close()
    if peak > budget:
        raise RuntimeError(
            f"serving transcode guard: peak resident {peak} exceeded the "
            f"{budget}-byte ledger budget")
    common.csv_row(
        "serving/transcode", wall_s * 1e6,
        f"fields={len(names)};peak_resident_mb={peak / 2**20:.1f};"
        f"budget_mb={budget / 2**20:.0f};"
        f"src_bytes={os.path.getsize(path)};"
        f"dst_bytes={os.path.getsize(dst)}")
