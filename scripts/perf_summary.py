"""Summarize the §Perf iteration records (experiments/perf + baselines)."""
import json

CELLS = {
    "A (qwen3-8b train_4k 16x16)": [
        ("A0 baseline", "experiments/dryrun/qwen3-8b_train_4k_single.json"),
        ("A1 skip_uncausal [adopted]",
         "experiments/perf/qwen3-8b_train_4k_single_A1_skipuncausal.json"),
        ("A2 remat=dots [rejected: HBM]",
         "experiments/perf/qwen3-8b_train_4k_single_A2_dots.json"),
        ("A3 seq-shard inputs [refuted]",
         "experiments/perf/qwen3-8b_train_4k_single_A3_seqshard.json"),
        ("A4 microbatch=16",
         "experiments/perf/qwen3-8b_train_4k_single_A4_mb16.json"),
        ("A5 A1+sp_residual",
         "experiments/perf/qwen3-8b_train_4k_single_A5_skipunc_sp.json"),
        ("A6 A5+mb2",
         "experiments/perf/qwen3-8b_train_4k_single_A6_skipunc_sp_mb2.json"),
        ("A7 A1+mb2 [rejected: HBM]",
         "experiments/perf/qwen3-8b_train_4k_single_A7_skipunc_mb2.json"),
    ],
    "B (deepseek-moe train_4k 2x16x16)": [
        ("B0 baseline group=2048",
         "experiments/dryrun/deepseek-moe-16b_train_4k_multi.json"),
        ("B1 group=256 [adopted]",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B1_group256.json"),
        ("B2 B1+seq-shard [refuted]",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B2_group256_seqshard.json"),
        ("B3 B1+remat=dots",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B3_group256_dots.json"),
        ("B4 B1+sp_residual",
         "experiments/perf/deepseek-moe-16b_train_4k_multi_B4_group256_sp.json"),
    ],
    "C (neurlz_enhance 16x16)": [
        ("C0 baseline pjit+vmap",
         "experiments/dryrun/neurlz_enhance_na_single.json"),
        ("C1 shard_map [adopted]",
         "experiments/perf/neurlz_enhance_na_single_C1_shardmap.json"),
    ],
}


def main():
    for cell, rows in CELLS.items():
        print(f"\n## {cell}")
        print(f"{'iteration':38s} {'comp_ms':>9s} {'mem_ms':>9s} "
              f"{'coll_ms':>9s} {'HBM_GiB':>8s} {'useful':>7s}")
        for label, path in rows:
            try:
                r = json.load(open(path))
            except FileNotFoundError:
                print(f"{label:38s} (missing)")
                continue
            t = r["roofline"]
            u = r.get("useful_compute_ratio")
            print(f"{label:38s} {t['compute_s']*1e3:9.1f} "
                  f"{t['memory_s']*1e3:9.1f} {t['collective_s']*1e3:9.1f} "
                  f"{r['memory']['peak_hbm_bytes']/2**30:8.2f} "
                  f"{u if u is None else format(u, '.3f'):>7}")


if __name__ == "__main__":
    main()
