"""End-to-end NeurLZ driver (the paper's workload): multi-field block,
cross-field learning, per-field error bounds, strict error regulation,
archive on disk, full validation report — on the first-class session API
(``repro.NeurLZ`` / ``repro.Archive``).

    PYTHONPATH=src python examples/compress_field.py [--dataset nyx]
        [--shape 32,48,48] [--eb 1e-3] [--epochs 8] [--mode strict]
        [--field-eb name=1e-2 --field-eb other=abs:0.5:relaxed]
"""
import argparse
import os
import resource
import sys
import tempfile

import numpy as np

import repro
from repro.compressors import registry
from repro.core import metrics
from repro.data import fields as F


def list_compressors() -> None:
    """Print the compressor registry (names, capabilities, archive kinds)."""
    print(f"{'name':18s} {'kind':10s} {'batchable':9s} {'dec_batch':9s} "
          f"{'dtypes':18s} description")
    for e in registry.entries():
        dts = ",".join(e.dtypes)
        print(f"{e.name:18s} {e.kind:10s} {str(e.batchable):9s} "
              f"{str(e.decode_batchable):9s} {dts:18s} {e.description}")


def parse_field_eb(spec: str) -> tuple[str, repro.ErrorBound]:
    """``name=1e-2`` (relative) | ``name=abs:0.5`` | ``name=1e-3:relaxed``
    | ``name=abs:0.5:strict`` -> per-field ErrorBound."""
    name, _, rest = spec.partition("=")
    if not rest:
        raise argparse.ArgumentTypeError(f"bad --field-eb {spec!r}")
    parts = rest.split(":")
    kind = "rel"
    if parts[0] in ("rel", "abs"):
        kind = parts.pop(0)
    if not parts:
        raise argparse.ArgumentTypeError(
            f"bad --field-eb {spec!r}: missing bound value after {kind!r}")
    try:
        value = float(parts.pop(0))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"bad --field-eb {spec!r}: bound value must be a number")
    mode = parts.pop(0) if parts else None
    return name, repro.ErrorBound(rel=value if kind == "rel" else None,
                                  abs=value if kind == "abs" else None,
                                  mode=mode)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="nyx",
                    choices=["nyx", "miranda", "hurricane"])
    ap.add_argument("--shape", default="32,48,48")
    ap.add_argument("--eb", type=float, default=1e-3,
                    help="default value-range-relative bound")
    ap.add_argument("--field-eb", action="append", default=[],
                    metavar="NAME=[rel:|abs:]VALUE[:MODE]",
                    help="per-field bound override (repeatable), e.g. "
                         "velocity_x=1e-2 or temperature=abs:0.5:relaxed")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--mode", default="strict",
                    choices=["strict", "relaxed", "unregulated"])
    ap.add_argument("--compressor", default="szlike",
                    choices=registry.names(),
                    help="conventional stage (any registered compressor)")
    ap.add_argument("--list-compressors", action="store_true",
                    help="print the compressor registry and exit")
    ap.add_argument("--engine", default="batched",
                    choices=["serial", "batched", "streaming"],
                    help="batched = multi-field fused-dispatch engine; "
                         "streaming = bounded-memory pipeline + async "
                         "archive writer (both bit-identical to serial)")
    ap.add_argument("--lowering", default="auto",
                    choices=["eager", "jit", "pallas", "auto"],
                    help="kernel lowering for the hot path; non-eager "
                         "variants engage only where their byte-parity "
                         "probe passes, so archives are identical either "
                         "way (auto = fastest proven lowering)")
    ap.add_argument("--max-resident-mb", type=float, default=0.0,
                    help="streaming engine residency budget in MiB "
                         "(0 = track peak only, no ceiling)")
    ap.add_argument("--decode-field", default=None,
                    help="also time a lazy single-field random-access "
                         "decode of this field (streaming archives)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record telemetry and write a Chrome/Perfetto "
                         "trace_event JSON here (load it at ui.perfetto.dev);"
                         " PATH.jsonl gets the line-per-event log")
    ap.add_argument("--resume", action="store_true",
                    help="streaming engine: salvage a partial container at "
                         "--out left by a killed run (same config) and "
                         "compress only the remaining fields")
    ap.add_argument("--verify", action="store_true",
                    help="after compressing, re-read every entry through "
                         "the checksum path and report per-entry status")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.list_compressors:
        list_compressors()
        return

    shape = tuple(int(s) for s in args.shape.split(","))
    flds = F.make_fields(args.dataset, shape=shape, seed=0)
    cross = F.DEFAULT_CROSS_FIELD[args.dataset]
    try:
        bounds = dict(parse_field_eb(s) for s in args.field_eb)
    except argparse.ArgumentTypeError as exc:
        ap.error(str(exc))

    tel = repro.Telemetry() if args.trace_out else None
    sess = repro.NeurLZ(
        model=repro.ModelConfig(epochs=args.epochs, cross_field=cross),
        engine=repro.EngineConfig(
            engine=args.engine, compressor=args.compressor,
            lowering=args.lowering,
            max_resident_bytes=int(args.max_resident_mb * 2**20),
            telemetry=tel),
        regulation=repro.RegulationConfig(mode=args.mode))
    print(f"[compress] {args.dataset} {shape} eb={args.eb} mode={args.mode} "
          f"epochs={args.epochs} cross_field=on engine={args.engine} "
          f"lowering={args.lowering}"
          + (f" field_eb={ {n: (b.rel, b.abs, b.mode) for n, b in bounds.items()} }"
             if bounds else ""))
    path = args.out or os.path.join(
        tempfile.gettempdir(),
        f"{args.dataset}.nlzs" if args.engine == "streaming"
        else f"{args.dataset}.nlz")
    if args.resume and args.engine != "streaming":
        ap.error("--resume requires --engine streaming (the incremental "
                 "container is what a killed run leaves behind)")
    if args.engine == "streaming":
        # Full out-of-core path: incremental container straight to disk,
        # reopened as a *lazy* Archive handle (no field materializes until
        # decoded).
        arc = sess.compress_to(flds, path, bounds=bounds or None,
                               rel_eb=args.eb, resume=args.resume)
        report = arc.report
        nbytes = report["bytes_written"]
        if args.resume:
            done = report["resumed_fields"]
            print(f"[resume]   salvaged {len(done)} field"
                  f"{'s' if len(done) != 1 else ''} from the partial "
                  f"container" + (f": {', '.join(done)}" if done else ""))
        print(f"[resident] pipeline peak {report['peak_resident_bytes']/2**20:.2f} MiB"
              + (f" (budget {args.max_resident_mb:.2f} MiB)"
                 if args.max_resident_mb else " (no ceiling)")
              + f", writer busy {report['writer_busy_s']:.2f}s")
        if report["degraded_fields"]:
            print(f"[degraded] conv-only fallback (bound still honored): "
                  f"{', '.join(report['degraded_fields'])}")
    else:
        arc = sess.compress(flds, bounds=bounds or None, rel_eb=args.eb)
        nbytes = arc.save(path)
    if args.verify:
        rep = arc.verify()
        bad = {n: e for n, e in rep["entries"].items() if not e["ok"]}
        print(f"[verify]   {len(rep['entries'])} entries checksum-verified: "
              + ("all ok" if rep["ok"] else f"{len(bad)} FAILED {bad}"))
        assert rep["ok"], "container verification failed"
    cs = arc["timing"].get("conv_stage")
    if cs:
        print(f"[conv]     {cs['fields']} fields -> {cs['groups']} groups, "
              f"{cs['calls']} compressor calls "
              f"({cs['batched_fields']} batched / "
              f"{cs['fallback_fields']} per-field), {cs['conv_s']:.2f}s")
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    rss_b = rss if sys.platform == "darwin" else rss * 1024
    print(f"[archive]  {path}  ({nbytes/2**20:.2f} MiB on disk, "
          f"process peak RSS {rss_b/2**20:.0f} MiB)")

    # Decode from disk to prove the round-trip (lazy open for streaming).
    with repro.Archive.open(path) as arc_disk:
        if args.decode_field:
            import time
            t0 = time.time()
            one = arc_disk.decode(args.decode_field)
            t1 = time.time() - t0
            reads = (len(arc_disk.reader.entry_reads)
                     if arc_disk.streaming else len(flds))
            print(f"[random]   decode({args.decode_field!r}) {t1*1e3:.0f} ms, "
                  f"{reads} entr{'y' if reads == 1 else 'ies'} read, "
                  f"{one.nbytes/2**20:.2f} MiB out")
        dec = sess.decompress(arc_disk)
    raw = sum(v.nbytes for v in flds.values())
    br = arc.bitrate()
    total = sum(br[n]["total_bytes"] for n in flds)
    print(f"[totals]   raw {raw/2**20:.1f} MiB -> {total/2**20:.2f} MiB "
          f"(CR {raw/total:.1f}x)")
    for name, x in flds.items():
        entry = arc["fields"][name]
        eb = entry["abs_eb"]
        mode = entry["mode"]
        err = np.abs(dec[name].astype(np.float64) - x.astype(np.float64)).max()
        conv = registry.decompress(entry["conv"])
        print(f"  {name:22s} [{mode:11s}] maxerr/eb={err/eb:6.3f}  "
              f"PSNR {metrics.psnr(x, conv):6.2f} -> {metrics.psnr(x, dec[name]):6.2f} dB  "
              f"bitrate {br[name]['bitrate']:6.3f} b/val")
        limit = eb if mode == "strict" else (
            2 * eb if mode == "relaxed" else np.inf)
        assert err <= limit * (1 + 1e-9), "bound violated!"
    print("[ok] all error bounds verified")

    if tel is not None:
        tel.export_chrome_trace(args.trace_out)
        tel.export_jsonl(args.trace_out + ".jsonl")
        s = tel.summary()
        top = sorted(s["spans"].items(), key=lambda kv: -kv[1]["wall_s"])[:6]
        print(f"[trace]    {args.trace_out} (+.jsonl): "
              f"{sum(a['count'] for _, a in top)} spans, top wall: "
              + ", ".join(f"{n} {a['wall_s']:.2f}s" for n, a in top))


if __name__ == "__main__":
    main()
