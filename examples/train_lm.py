"""Train a ~16M-param qwen3-family model for a few hundred steps with the
full framework: checkpointing (optionally NeurLZ-compressed), resume,
straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --lossy-ckpt
"""
import argparse
from types import SimpleNamespace

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_run")
    ap.add_argument("--lossy-ckpt", action="store_true",
                    help="NeurLZ error-bounded checkpoint weights (eb=1e-5)")
    args = ap.parse_args()
    train(SimpleNamespace(
        arch=args.arch, preset="reduced", steps=args.steps, batch=args.batch,
        seq=args.seq, lr=3e-3, seed=0, microbatch=1,
        ckpt_dir=args.ckpt_dir, ckpt_every=50, keep=3, resume=True,
        lossy_ckpt_eb=1e-5 if args.lossy_ckpt else None,
        fail_at_step=None, step_deadline=300.0, log_every=20))


if __name__ == "__main__":
    main()
