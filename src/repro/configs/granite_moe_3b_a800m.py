"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff_expert=512
vocab=49155, MoE 40 experts top-8  [hf:ibm-granite/granite-3.0-3b-a800m-base;
hf]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe", n_layers=32, d_model=1536,
    n_heads=24, n_kv_heads=8, head_dim=64, d_ff=512, vocab_size=49155,
    act="silu", rope_theta=1e4, tie_embeddings=True,
    moe=True, n_experts=40, top_k=8, d_ff_expert=512,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=64,
                               vocab_size=256, n_experts=8, top_k=2,
                               d_ff_expert=64, moe_group_size=64,
                               dtype="float32")
