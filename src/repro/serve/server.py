"""`ArchiveServer` — concurrent decode requests over NeurLZ archives.

One dispatcher thread drains the :class:`~repro.serve.coalesce.Coalescer`
in batches and serves each batch through three tiers:

1. **Cache** — hot decoded fields come straight out of the
   :class:`~repro.serve.cache.HotFieldCache` (bytes charged to the shared
   :class:`~repro.streaming.pipeline.ResidencyLedger`).
2. **Coalesced decode** — cache misses for plain whole-field entries are
   folded into *one* ``registry.decompress_many`` call per batch; archives
   agreeing on the registry ``decode_key`` (same compressor, shape, dtype,
   layout) execute as a single stacked ``decompress_batched`` dispatch.
   The :class:`~repro.compressors.registry.DecodeStats` counters expose
   exactly how many dispatches ran — the coalescing guarantee the tests
   and the ``bench_serving`` smoke guard assert.
3. **Individual decode** — ROI requests and ``BlockedSource`` originals
   delegate to :meth:`Archive.decode` (which itself reads only covering
   blocks for a ROI).

Aux-closure reconstructions decoded along the way are cached under
``("aux", ...)`` keys and **pinned** for the duration of any batch whose
decodes depend on them — the cache never evicts a closure out from under
an in-flight decode.  Failures (including injected faults at site
``"serve.request"``) fail the affected request's future; the server keeps
serving everything else.
"""
from __future__ import annotations

import os
import threading

from ..compressors import registry
from ..core import neurlz
from ..core.archive_api import Archive
from ..faults import DEFAULT as FAULTS_DEFAULT
from ..obs import telemetry as obs_lib
from ..streaming.pipeline import ResidencyLedger
from .cache import HotFieldCache
from .coalesce import Coalescer, Future, Request

_MISS = object()


def _roi_key(roi):
    """Hashable form of a ROI spec (slices are unhashable)."""
    if roi is None:
        return None
    if isinstance(roi, slice):
        roi = (roi,)
    return tuple((s.start, s.stop, s.step) for s in roi)


class ArchiveServer:
    """Multi-tenant decode/transcode front end over open archives.

    ``archives`` maps an archive id to an :class:`Archive`, an archive
    dict, or a path (opened lazily on first touch is *not* done — paths
    open at registration so bad paths fail fast).  A single archive (or
    path) registers under id ``"default"``.

    ``ledger`` is the shared residency ledger the cache charges; pass the
    one your streaming jobs use for a single process-wide ceiling, or let
    the server build its own from ``max_bytes``.

    The dispatcher thread starts immediately unless ``auto_start=False``
    (tests queue requests first and call :meth:`start` for a
    deterministic coalescing window).  ``copy_results=True`` (default)
    hands each caller its own array; disable to share the cached buffer
    (fast, but callers must not mutate it).
    """

    def __init__(self, archives=None, *, ledger: ResidencyLedger | None = None,
                 max_bytes: int = 0, telemetry=None, faults=None,
                 window_s: float = 0.002, max_batch: int = 64,
                 auto_start: bool = True, copy_results: bool = True):
        self.telemetry = telemetry if telemetry is not None else obs_lib.NULL
        self.faults = faults if faults is not None else FAULTS_DEFAULT
        self.ledger = ledger if ledger is not None \
            else ResidencyLedger(max_bytes, telemetry=self.telemetry)
        self.cache = HotFieldCache(self.ledger, self.telemetry)
        self.decode_stats = registry.DecodeStats()
        self.copy_results = bool(copy_results)
        self._coalescer = Coalescer(window_s=window_s, max_batch=max_batch)
        self._archives: dict[str, Archive] = {}
        self._lock = threading.Lock()
        self._thread: threading.Thread | None = None
        self._root_span = None
        self._requests = 0
        if archives is not None:
            if isinstance(archives, dict) and not archives.get("kind"):
                for aid, src in archives.items():
                    self.add_archive(src, archive_id=aid)
            else:
                self.add_archive(archives, archive_id="default")
        if auto_start:
            self.start()

    # -- archive registry ---------------------------------------------------

    def add_archive(self, src, archive_id: str | None = None) -> str:
        """Register an archive (handle, dict, or container path) and return
        its id."""
        if isinstance(src, (str, bytes, os.PathLike)):
            arc = Archive.open(src)
        elif isinstance(src, Archive):
            arc = src
        else:
            arc = Archive.from_dict(src)
        if arc.telemetry is obs_lib.NULL:
            arc.telemetry = self.telemetry
        if archive_id is None:
            archive_id = arc.path or f"archive{len(self._archives)}"
        with self._lock:
            self._archives[archive_id] = arc
        return archive_id

    def remove_archive(self, archive_id: str) -> None:
        with self._lock:
            self._archives.pop(archive_id, None)
        for key in self.cache.keys:
            # main keys are (aid, name, roi); aux keys ("aux", aid, name)
            aid = key[1] if key and key[0] == "aux" else key[0]
            if aid == archive_id:
                self.cache.invalidate(key)

    @property
    def archive_ids(self) -> list[str]:
        with self._lock:
            return list(self._archives)

    def _resolve(self, archive_id: str | None) -> tuple[str, Archive]:
        with self._lock:
            if archive_id is None:
                if len(self._archives) != 1:
                    raise ValueError(
                        f"archive_id required: server holds "
                        f"{len(self._archives)} archives")
                archive_id = next(iter(self._archives))
            return archive_id, self._archives[archive_id]

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "ArchiveServer":
        """Start the dispatcher thread (idempotent)."""
        if self._thread is None or not self._thread.is_alive():
            if self._root_span is None:
                self._root_span = self.telemetry.span("serve", root=True)
                self._root_span.__enter__()
            self._thread = threading.Thread(target=self._dispatch_loop,
                                            name="repro-serve", daemon=True)
            self._thread.start()
        return self

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def close(self, *, close_archives: bool = False) -> None:
        """Drain outstanding requests, stop the dispatcher, release the
        cache's ledger charges."""
        self._coalescer.close()
        if self._thread is not None:
            if not self.running and self._coalescer.pending():
                self._drain_all()       # never started: serve synchronously
            else:
                self._thread.join()
        elif self._coalescer.pending():
            self._drain_all()
        if self._root_span is not None:
            self._root_span.__exit__(None, None, None)
            self._root_span = None
        self.cache.clear()
        if close_archives:
            for arc in self._archives.values():
                arc.close()

    def __enter__(self) -> "ArchiveServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- request surface ----------------------------------------------------

    def submit(self, name: str, *, archive_id: str | None = None,
               roi=None) -> Future:
        """Enqueue a decode request; returns a future whose ``result()``
        is the decoded (optionally ROI-sliced) field array."""
        aid, _ = self._resolve(archive_id)
        req = Request(aid, name, roi)
        self.telemetry.counter("serve.requests").add()
        with self._lock:
            self._requests += 1
        self._coalescer.submit(req)
        return req.future

    def decode(self, name: str, *, archive_id: str | None = None, roi=None,
               timeout: float | None = 30.0):
        """Blocking convenience: ``submit(...).result(timeout)``."""
        if not self.running:
            raise RuntimeError("server not started (auto_start=False?) — "
                               "call start() or use submit() + start()")
        return self.submit(name, archive_id=archive_id,
                           roi=roi).result(timeout)

    def stats(self) -> dict:
        """Serving counters: requests, cache hits/misses/evictions, decode
        dispatch accounting (the coalescing evidence), ledger residency."""
        return {
            "requests": self._requests,
            "decode": self.decode_stats.as_dict(),
            "counters": self.telemetry.counters_prefixed("serve."),
            "cache_entries": len(self.cache),
            "resident_bytes": self.ledger.current,
            "max_bytes": self.ledger.max_bytes,
        }

    # -- dispatcher ---------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            batch, stopping = self._coalescer.drain()
            if batch:
                self.telemetry.gauge("serve.coalesce_width").set(len(batch))
                self._serve_batch(batch)
            if stopping:
                return

    def _drain_all(self) -> None:
        """Synchronous fallback drain (server closed before start)."""
        while True:
            batch, stopping = self._coalescer.drain(block=False)
            if batch:
                self._serve_batch(batch)
            if stopping or not batch:
                return

    def _out(self, value):
        return value.copy() if self.copy_results else value

    def _serve_batch(self, batch: list[Request]) -> None:
        with self.telemetry.span("serve.batch", requests=len(batch)):
            coalesce: list = []     # (req, arc, cache_key) plain whole-field
            individual: list = []   # (req, arc, cache_key) roi / blocked
            for req in batch:
                with self._lock:
                    arc = self._archives.get(req.archive_id)
                if arc is None:
                    self._fail(req, KeyError(
                        f"unknown archive id {req.archive_id!r}"))
                    continue
                key = (req.archive_id, req.name, _roi_key(req.roi))
                hit = self.cache.get(key, _MISS)
                if hit is not _MISS:
                    req.future.set_result(self._out(hit))
                    continue
                if req.roi is None and req.name not in arc.block_manifest:
                    coalesce.append((req, arc, key))
                else:
                    individual.append((req, arc, key))
            self._serve_coalesced(coalesce)
            for req, arc, key in individual:
                self._serve_one(req, arc, key)

    def _fail(self, req: Request, exc: BaseException) -> None:
        self.telemetry.counter("serve.request_errors").add()
        req.future.set_error(exc)

    def _serve_one(self, req: Request, arc: Archive, key) -> None:
        with self.telemetry.span("serve.request", field=req.name,
                                 archive=req.archive_id, kind="individual"):
            try:
                value = self.faults.run(
                    lambda: arc.decode(req.name, roi=req.roi),
                    site="serve.request", tel=self.telemetry)
            except Exception as exc:  # noqa: BLE001 - request isolation
                self._fail(req, exc)
                return
            self.cache.put(key, value)
            req.future.set_result(self._out(value))

    def _serve_coalesced(self, items: list) -> None:
        """Serve plain whole-field cache misses as one registry call.

        Same-``decode_key`` conventional archives across *all* requests in
        the batch (any tenant) stack into single ``decompress_batched``
        dispatches inside :func:`registry.decompress_many`.
        """
        if not items:
            return
        by_field: dict[tuple, list] = {}    # (aid, name) -> [(req, arc, key)]
        for item in by_order(items):
            by_field.setdefault((item[0].archive_id, item[0].name),
                                []).append(item)
        conv: dict[tuple, dict] = {}        # (aid, entry_name) -> conv arc
        entries: dict[tuple, dict] = {}
        cached_aux: dict[tuple, object] = {}
        pinned: list = []
        failed: dict[tuple, BaseException] = {}
        for (aid, name), reqs in by_field.items():
            arc = reqs[0][1]
            try:
                self.faults.run(lambda: None, site="serve.request",
                                tel=self.telemetry)
                e = arc._entry_transient(name)
                entries[(aid, name)] = e
                conv[(aid, name)] = e["conv"]
                for a in e["aux"]:
                    akey = ("aux", aid, a)
                    if (aid, a) in conv or (aid, a) in cached_aux:
                        continue
                    rec = self.cache.get(akey, _MISS)
                    if rec is not _MISS:
                        self.cache.pin(akey)
                        pinned.append(akey)
                        cached_aux[(aid, a)] = rec
                    else:
                        conv[(aid, a)] = arc._entry_transient(a)["conv"]
            except Exception as exc:  # noqa: BLE001 - request isolation
                failed[(aid, name)] = exc
                conv.pop((aid, name), None)
        try:
            if conv:
                with self.telemetry.span("serve.decode",
                                         fields=len(by_field),
                                         archives=len(conv)):
                    recs = registry.decompress_many(conv,
                                                    stats=self.decode_stats)
            else:
                recs = {}
            recs.update(cached_aux)
            for (aid, name), reqs in by_field.items():
                arc, key = reqs[0][1], reqs[0][2]
                exc = failed.get((aid, name))
                if exc is None:
                    try:
                        e = entries[(aid, name)]
                        value = neurlz.decode_field_entry(
                            e, recs[(aid, name)],
                            [recs[(aid, a)] for a in e["aux"]],
                            arc["slice_axis"])
                    except Exception as err:  # noqa: BLE001
                        exc = err
                if exc is not None:
                    for req, _, _ in reqs:
                        self._fail(req, exc)
                    continue
                self.cache.put(key, value)
                for a in e["aux"]:
                    akey = ("aux", aid, a)
                    if akey not in pinned:
                        self.cache.put(akey, recs[(aid, a)])
                for req, _, _ in reqs:
                    req.future.set_result(self._out(value))
        finally:
            for akey in pinned:
                self.cache.unpin(akey)

    def __repr__(self) -> str:
        state = "running" if self.running else "stopped"
        return (f"<ArchiveServer {state} archives={len(self._archives)} "
                f"cache={len(self.cache)} requests={self._requests}>")


def by_order(items):
    """Stable request-order iteration (requests carry a global seq)."""
    return sorted(items, key=lambda it: it[0].seq)
