"""Fused enhancement + error regulation — Pallas TPU kernel (paper §3.3).

Decode-side hot path, fused into one VMEM pass per tile:

    r̂        = (2·σ(z) − 1) · eb          (balanced 2× regulation, Fig. 6B)
    enhanced  = decomp + r̂
    outlier   = |enhanced − orig| > eb      (encode side only)
    final     = outlier ? decomp : enhanced (strict 1× mode, Fig. 5)

Unfused, this is four elementwise HBM round-trips over ≥512² planes; fused
it reads (z, decomp, orig) once and writes (final, mask) once — the op is
purely bandwidth-bound, so the fusion is the whole win.  The same kernel
serves decode (orig := decomp makes the mask all-False and ``final`` the
relaxed-mode enhancement).

Tiling: elementwise over (rows, cols) tiles of the flattened-to-2D field;
the row tile is sized to VMEM, with the last column dimension kept at the
field's W (≤512) so tiles are lane-aligned (multiple of 128 for fp32 when W
is — fields are 4³-padded upstream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, dec_ref, orig_ref, out_ref, mask_ref, *, eb: float,
            regulated: bool, strict: bool):
    z = z_ref[...]
    dec = dec_ref[...]
    orig = orig_ref[...]
    if regulated:
        resid = (2.0 * jax.nn.sigmoid(z.astype(jnp.float32)) - 1.0) * eb
    else:
        resid = z.astype(jnp.float32) * eb
    enh = (dec.astype(jnp.float32) + resid).astype(dec.dtype)
    bad = jnp.abs(enh.astype(jnp.float32) - orig.astype(jnp.float32)) > eb
    if strict:
        out_ref[...] = jnp.where(bad, dec, enh)
    else:
        out_ref[...] = enh
    mask_ref[...] = bad.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("eb", "regulated", "strict", "tr", "interpret"))
def fused_enhance(z: jax.Array, decomp: jax.Array, orig: jax.Array, eb: float,
                  *, regulated: bool = True, strict: bool = True, tr: int = 256,
                  interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """All inputs (R, W) 2-D (ops.py reshapes/pads fields).  Returns
    (final same-dtype-as-decomp, outlier mask uint8)."""
    rows, cols = z.shape
    assert rows % tr == 0, (rows, tr)
    kernel = functools.partial(_kernel, eb=float(eb), regulated=regulated,
                               strict=strict)
    spec = pl.BlockSpec((tr, cols), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // tr,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(decomp.shape, decomp.dtype),
            jax.ShapeDtypeStruct(decomp.shape, jnp.uint8),
        ],
        interpret=interpret,
    )(z, decomp, orig)
