"""Model registry + input specs + jit-able step functions.

``input_specs(cfg, shape)`` returns ShapeDtypeStructs for every model input
(weak-type-correct, shardable, no allocation) — the dry-run lowers directly
against them.  ``train_step`` fuses loss/grad/AdamW; ``decode_step`` is the
serving inner loop (one new token against a KV/state cache).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..optim import adamw_init, adamw_update
from .transformer import Model


def build_model(cfg: ModelConfig, model_axis: int = 16) -> Model:
    return Model(cfg, model_axis=model_axis)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    dt = cfg.params_dtype
    if shape.kind == "decode":
        # decode inputs: one token per sequence (cache specs built separately)
        if cfg.family == "audio":
            raise ValueError("encoder-only arch has no decode step")
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.family == "audio":
        return {
            "features": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
    if cfg.family == "vlm":
        s_img = cfg.frontend_tokens
        return {
            "tokens": jax.ShapeDtypeStruct((b, s - s_img), jnp.int32),
            "image_embeds": jax.ShapeDtypeStruct((b, s_img, cfg.d_model), dt),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def demo_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """Concrete random batch matching input_specs (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        return {
            "features": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), cfg.params_dtype),
            "mask": jnp.asarray(rng.random((batch, seq)) < max(cfg.mask_ratio, 0.08)),
            "targets": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    if cfg.family == "vlm":
        s_img = cfg.frontend_tokens
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq - s_img)), jnp.int32),
            "image_embeds": jnp.asarray(
                rng.standard_normal((batch, s_img, cfg.d_model)) * 0.02,
                cfg.params_dtype),
        }
    return {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_train_step(model: Model, *, lr: float = 3e-4, grad_clip: float = 1.0,
                    weight_decay: float = 0.1, remat_policy: str = "nothing",
                    lr_fn=None, microbatch: int = 1):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``microbatch > 1`` scans gradient accumulation over batch slices —
    per-step activation memory drops by the same factor (the standard
    fit-in-HBM lever; grads accumulate in f32)."""

    def train_step(params, opt_state, batch, step):
        def loss_fn(p, b):
            return model.loss(p, b, remat_policy=remat_policy)

        if microbatch > 1:
            def split(x):
                return x.reshape((microbatch, x.shape[0] // microbatch)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def mb_step(acc, mbatch):
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return acc, l

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, losses = jax.lax.scan(mb_step, zeros, mb)
            grads = jax.tree.map(
                lambda g, p: (g / microbatch).astype(p.dtype), gsum, params)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        cur_lr = lr_fn(step) if lr_fn is not None else lr
        params, opt_state = adamw_update(
            grads, opt_state, params, lr=cur_lr, weight_decay=weight_decay,
            grad_clip_norm=grad_clip)
        return params, opt_state, {"loss": loss, "lr": cur_lr * jnp.ones(())}

    return train_step


def make_prefill_step(model: Model, *, remat_policy: str = "nothing"):
    """Forward only: hidden states for the full prompt (serving prefill)."""

    def prefill_step(params, batch):
        hidden = model.forward(params, batch, remat_policy=remat_policy)
        # Last-position logits are what serving returns after prefill.
        logits = model._logits(params, hidden[:, -1:]).astype(jnp.float32)
        return logits

    return prefill_step


def make_encode_step(model: Model, *, remat_policy: str = "nothing"):
    """Encoder-only forward (hubert): per-frame logits."""

    def encode_step(params, batch):
        hidden = model.forward(params, batch, remat_policy=remat_policy)
        return model._logits(params, hidden).astype(jnp.float32)

    return encode_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return decode_step


def init_params(model: Model, seed: int = 0):
    return model.init(jax.random.PRNGKey(seed))


def init_train_state(model: Model, seed: int = 0):
    params = init_params(model, seed)
    return params, adamw_init(params)


def abstract_params(model: Model):
    """ShapeDtypeStruct tree of the params — dry-run init (no allocation)."""
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def abstract_opt_state(abstract_p):
    return jax.eval_shape(lambda p: adamw_init(p), abstract_p)


def abstract_cache(model: Model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))
