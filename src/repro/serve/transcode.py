"""Transcode proxy: re-target a stored archive to new error bounds.

``transcode(src, dst, bounds=...)`` reads a source archive's entries
lazily — :class:`ArchiveSource` adapts an open :class:`Archive` to the
streaming engine's :class:`ChunkedFieldSource` protocol, decoding one
field (or ``BlockedSource`` block) at a time on ``load`` — and
re-compresses them under new per-field :class:`ErrorBound` specs through
the regular streaming pipeline into a fresh container.  Because it *is*
the streaming pipeline underneath:

* residency stays under the :class:`ResidencyLedger` budget (pass the
  serving tier's ledger to share one process-wide ceiling with the
  hot-field cache);
* the output is **byte-identical per entry** to decoding the whole
  snapshot and recompressing it under the same config/bounds (the
  pipeline's determinism contract — transcoding buys memory, not
  different bytes);
* ``resume=True`` salvages a partial destination from a killed transcode
  and re-compresses only the missing fields (PR 8 machinery).

Block structure carries through: a blocked source field stays blocked
with the same spans in the destination (``ArchiveSource`` re-exposes the
manifest), and ``bounds`` keyed by *original* field names are expanded
onto their block entries.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Mapping

from ..core import neurlz
from ..core.archive_api import Archive
from ..streaming import pipeline
from ..streaming import source as source_lib


class ArchiveSource:
    """A :class:`ChunkedFieldSource` view of an open archive.

    ``names``/``meta`` come from the archive index (entries read
    *transiently* for shape/dtype — nothing stays resident); ``load``
    decodes one entry on demand and may be called repeatedly, exactly the
    re-loadable contract the streaming pipeline expects.  Block entries
    are exposed as-is and the reassembly ``manifest`` is re-exported so a
    transcode preserves the source's block structure.
    """

    def __init__(self, archive):
        if isinstance(archive, (str, bytes, os.PathLike)):
            archive = Archive.open(archive)
        else:
            archive = Archive.from_dict(archive)
        self.archive = archive
        self.manifest = dict(archive.block_manifest)
        self._metas: dict[str, source_lib.FieldMeta] = {}
        # The pipeline's prefetch thread and main thread may both load;
        # the underlying reader seeks a shared file handle, so serialize.
        self._lock = threading.Lock()

    @property
    def aux_map(self) -> dict[str, list]:
        """Entry name -> cross-field aux producers (from the container)."""
        if self.archive.streaming:
            return dict(self.archive.reader.meta.get("aux") or {})
        return {n: list(self.archive["fields"][n].get("aux", ()))
                for n in self.archive.field_names}

    def names(self) -> list[str]:
        return list(self.archive.field_names)

    def meta(self, name: str) -> source_lib.FieldMeta:
        with self._lock:
            if name not in self._metas:
                e = self.archive._entry_transient(name)
                conv = e["conv"]
                self._metas[name] = source_lib.FieldMeta.of(
                    conv["shape"], conv.get("dtype", "float32"))
            return self._metas[name]

    def load(self, name: str):
        with self._lock:
            return self.archive.decode(name)


def _expand_block_bounds(bounds, manifest: dict, names: list):
    """Rewrite ``bounds`` keys given as blocked *original* field names onto
    their ``name#bN`` block entries (one spec per block — blocks are
    independent entries with their own bounds)."""
    if not manifest or not isinstance(bounds, Mapping):
        return bounds
    present = set(names)
    out = {}
    for key, spec in bounds.items():
        man = manifest.get(key)
        if man is not None and key not in present:
            for bname, _, _ in man["blocks"]:
                out[bname] = spec
        else:
            out[key] = spec
    return out


def transcode(src, dst, bounds=None, *, rel_eb: float | None = None,
              abs_eb: float | None = None, config=None,
              ledger=None, resume: bool = False,
              collect_stats: bool = True, telemetry=None,
              faults=None) -> Archive:
    """Re-compress ``src`` (archive handle, dict, or path) into a fresh
    container at ``dst`` under new error bounds; returns a lazy
    :class:`Archive` over the result with the pipeline report attached.

    ``config`` defaults to a streaming :class:`NeurLZConfig` matching the
    source container (compressor, slice axis, cross-field aux map) — pass
    one to also change those.  ``ledger`` shares a residency ceiling with
    other subsystems (e.g. an :class:`ArchiveServer` cache).  ``bounds``
    accepts per-field specs keyed by entry *or* blocked original names.
    ``resume=True`` continues an interrupted transcode from ``dst``'s
    salvageable prefix; the finished container is byte-identical to an
    uninterrupted run.
    """
    source = ArchiveSource(src)
    if config is None:
        meta = source.archive.meta
        config = neurlz.NeurLZConfig(
            engine="streaming",
            compressor=meta.get("compressor", "szlike"),
            slice_axis=meta.get("slice_axis", 0),
            cross_field={n: tuple(a) for n, a in source.aux_map.items()
                         if a})
    elif config.engine != "streaming":
        config = dataclasses.replace(config, engine="streaming")
    if telemetry is not None and config.telemetry is None:
        config = dataclasses.replace(config, telemetry=telemetry)
    if faults is not None and config.faults is None:
        config = dataclasses.replace(config, faults=faults)
    bounds = _expand_block_bounds(bounds, source.manifest, source.names())
    if isinstance(dst, os.PathLike):
        dst = os.fspath(dst)
    report = pipeline.compress(source, dst, rel_eb, abs_eb=abs_eb,
                               config=config, bounds=bounds,
                               collect_stats=collect_stats, resume=resume,
                               ledger=ledger)
    out = Archive.open(dst)
    out.report = report
    if telemetry is not None:
        out.telemetry = telemetry
    return out
