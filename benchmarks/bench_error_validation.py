"""Paper Fig 11 / Fig 5: error-distribution validation — strict mode keeps
every point within 1x eb; distribution tightens vs the conventional one."""
from __future__ import annotations

import time

import numpy as np

from . import common
from repro import compressors as C
from repro.data import fields as F


def run(full: bool = False):
    shape = (32, 48, 48) if full else (24, 40, 40)
    flds = F.make_fields("nyx", shape=shape, seed=2)
    for name in ("temperature", "dark_matter_density"):
        x = flds[name]
        t0 = time.time()
        arc, dec, out, _ = common.run_neurlz(
            {name: x}, 1e-3, mode="strict", epochs=8 if full else 4)
        eb = arc["fields"][name]["abs_eb"]
        conv = C.decompress(arc["fields"][name]["conv"])
        err_conv = np.abs(conv.astype(np.float64) - x.astype(np.float64)) / eb
        err_enh = np.abs(dec[name].astype(np.float64) - x.astype(np.float64)) / eb
        common.csv_row(
            f"fig11/{name}", (time.time() - t0) * 1e6,
            f"max_conv={err_conv.max():.4f};max_enh={err_enh.max():.4f};"
            f"rms_conv={np.sqrt((err_conv**2).mean()):.4f};"
            f"rms_enh={np.sqrt((err_enh**2).mean()):.4f};"
            f"within_1x={float((err_enh <= 1.0).mean()):.6f}")
        assert err_enh.max() <= 1.0 + 1e-9


if __name__ == "__main__":
    run()
