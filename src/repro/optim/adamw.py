"""AdamW in pure JAX pytrees (no optax dependency).

Used by both the NeurLZ online enhancer trainer (paper config: Adam, lr 1e-2,
cosine annealing) and the LM training loop.  State mirrors the param tree, so
it inherits whatever sharding the params carry — FSDP-sharded optimizer state
falls out for free under pjit.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment, same tree as params
    nu: Any       # second moment



def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def adamw_update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
                 b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0,
                 grad_clip_norm: float | None = None):
    """One AdamW step.  ``lr`` may be a scalar array (schedule output)."""
    step = state.step + 1

    if grad_clip_norm is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
