"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 blocks + ONE shared attention block applied every 6th
position (weight sharing is zamba2's signature)  [arXiv:2411.15242; unverified]"""
import dataclasses

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584, n_heads=32,
    n_kv_heads=32, d_ff=14336, vocab_size=32000, act="gelu",
    hybrid_attn_every=6, ssm_state=64, ssm_expand=2, ssm_headdim=64,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(CONFIG, n_layers=7, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab_size=256,
                               hybrid_attn_every=3, ssm_state=16,
                               ssm_headdim=16, dtype="float32")
