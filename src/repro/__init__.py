"""NeurLZ-JAX: neural-enhanced scientific lossy compression (Jia et al.,
ICS'25) as a first-class feature of a multi-pod JAX training/serving
framework.

Subpackages (imported lazily — ``repro.core``/``repro.compressors`` enable
x64 for FP64 datasets; model/launch paths do not):
    core          the paper's pipeline (enhancer, online training, regulation)
    compressors   SZ3-style / Lorenzo / ZFP-style error-bounded codecs
    kernels       Pallas TPU kernels (+ ops/ref)
    models        the 10 assigned architectures
    configs       arch configs + shape suites
    distributed   sharding rules, elastic re-sharding
    optim         AdamW, schedules, compressed grad sync
    checkpoint    fault-tolerant checkpointing
    data          synthetic fields + token pipeline
    launch        mesh, dryrun, roofline, train, serve

Top-level API (lazy attributes, PEP 562 — importing ``repro`` alone stays
cheap and does not flip the x64 switch; touching any of these loads
``repro.core``):
    NeurLZ                    compression session (configured object API)
    Archive                   one handle over both archive container formats
    ErrorBound                per-field error-bound spec (rel/abs/mode)
    ModelConfig / EngineConfig / RegulationConfig
                              the structured session configuration
    NeurLZConfig              the flat legacy config (still accepted)
    Telemetry / TelemetryConfig
                              observability handle (``repro.obs``; spans,
                              counters, per-field learning traces)
    FaultConfig / FaultInjector / RetryPolicy / InjectedFault
                              fault-tolerance knobs (``repro.faults``;
                              injection, retry + backoff, degradation)
    CorruptArchiveError       typed container-corruption error (with the
                              failing byte offset)
    ArchiveServer / transcode serving tier (``repro.serve``; coalesced
                              concurrent decode + bound re-targeting)
    open(path)                Archive.open convenience
"""
__version__ = "1.0.0"

__all__ = ["NeurLZ", "Archive", "ArchiveServer", "ErrorBound", "ModelConfig",
           "EngineConfig", "RegulationConfig", "NeurLZConfig", "Telemetry",
           "TelemetryConfig", "FaultConfig", "FaultInjector", "InjectedFault",
           "RetryPolicy", "CorruptArchiveError", "open", "transcode"]

_API = frozenset(__all__)   # every lazy attribute resolves via repro.api


def __getattr__(name: str):
    if name in _API:
        from . import api
        value = getattr(api, name)
        globals()[name] = value        # cache for subsequent lookups
        return value
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | _API)
