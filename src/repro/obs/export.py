"""Telemetry exporters: JSONL event log, Chrome/Perfetto trace, summary.

Three consumers, three formats:

* :func:`write_jsonl` — an append-friendly line-per-event log (meta line,
  then one line per span / counter / gauge sample / learning-trace record).
  Greppable, ``jq``-able, and stable enough to diff across runs.
* :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome
  ``trace_event`` JSON (the format Perfetto and ``chrome://tracing``
  consume): spans become complete (``"X"``) events keyed by thread, so the
  streaming pipeline's reader/scheduler/writer overlap renders as a flame
  graph; gauges with sample trails become counter (``"C"``) tracks (e.g.
  resident bytes riding under the ledger ceiling).
* :meth:`Telemetry.summary` — the aggregated dict (defined on the handle;
  re-exported here for symmetry).
"""
from __future__ import annotations

import json
import os

from .telemetry import Telemetry

__all__ = ["write_jsonl", "chrome_trace", "write_chrome_trace", "summary"]


def _open_sink(sink, mode: str):
    if isinstance(sink, (str, bytes, os.PathLike)):
        return open(sink, mode), True
    return sink, False


def summary(tel: Telemetry) -> dict:
    return tel.summary()


def write_jsonl(tel: Telemetry, sink) -> int:
    """Write the run's events as JSON lines; returns lines written."""
    f, own = _open_sink(sink, "w")
    n = 0

    def emit(obj) -> None:
        nonlocal n
        f.write(json.dumps(obj, default=float) + "\n")
        n += 1

    try:
        emit({"type": "meta", "epoch_unix_s": tel.epoch,
              "dropped_spans": tel.dropped_spans})
        for s in tel.spans:
            emit({"type": "span", "id": s.id, "parent": s.parent,
                  "name": s.name, "thread": s.thread_name,
                  "t0_s": s.t0, "dur_s": s.dur, "cpu_s": s.cpu,
                  **({"attrs": s.attrs} if s.attrs else {})})
        for name, value in tel.counters.items():
            emit({"type": "counter", "name": name, "value": value})
        for name, g in tel._gauges.items():
            emit({"type": "gauge", "name": name, "last": g.value,
                  "min": g.vmin, "max": g.vmax})
        for field, records in tel.traces.items():
            for rec in records:
                emit({"type": "learning_trace", "field": field, **rec})
    finally:
        if own:
            f.close()
    return n


def chrome_trace(tel: Telemetry) -> dict:
    """The run as a Chrome ``trace_event`` dict (load in Perfetto)."""
    pid = os.getpid()
    events: list[dict] = []
    threads: dict[int, str] = {}
    for s in tel.spans:
        threads.setdefault(s.thread, s.thread_name)
        events.append({
            "ph": "X", "name": s.name, "cat": "neurlz",
            "pid": pid, "tid": s.thread,
            "ts": s.t0 * 1e6, "dur": s.dur * 1e6,
            "args": {**s.attrs, "cpu_ms": round(s.cpu * 1e3, 3)},
        })
    meta = [{"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
             "args": {"name": tname}} for tid, tname in threads.items()]
    counters = []
    for name, g in tel._gauges.items():
        for ts, value in g.samples:
            counters.append({"ph": "C", "name": name, "cat": "neurlz",
                             "pid": pid, "tid": 0, "ts": ts * 1e6,
                             "args": {name.rsplit(".", 1)[-1]: value}})
    return {"traceEvents": meta + events + counters,
            "displayTimeUnit": "ms",
            "otherData": {"counters": tel.counters,
                          "dropped_spans": tel.dropped_spans}}


def write_chrome_trace(tel: Telemetry, sink) -> int:
    """Serialize :func:`chrome_trace` to ``sink``; returns bytes written."""
    data = json.dumps(chrome_trace(tel), default=float)
    f, own = _open_sink(sink, "w")
    try:
        f.write(data)
    finally:
        if own:
            f.close()
    return len(data)
