"""Property-based tests (hypothesis) for the system's core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import compressors as C
from repro.compressors import outliers as OC
from repro.compressors.szlike import lorenzo_delta, lorenzo_undelta
from repro.compressors.zfplike import _fwd_lift, _inv_lift
from repro.core import archive as A

import jax.numpy as jnp


fields = st.integers(0, 10_000).map(
    lambda seed: _mk_field(seed))


def _mk_field(seed):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(4, 14, size=3))
    x = rng.standard_normal(shape)
    if seed % 3 == 0:  # spiky fields too
        x[tuple(rng.integers(0, s) for s in shape)] *= 100.0
    return np.cumsum(x, axis=0).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(fields, st.sampled_from([1e-2, 1e-3, 1e-4]),
       st.sampled_from(["szlike", "szlike-lorenzo", "zfplike"]))
def test_error_bound_invariant(x, eb, comp):
    """|decompress(compress(x)) - x| <= eb, always, for every compressor."""
    arc, rec = C.compress(x, eb, compressor=comp)
    dec = C.decompress(arc)
    assert np.abs(dec.astype(np.float64) - x.astype(np.float64)).max() <= arc["abs_eb"]
    assert np.array_equal(rec, dec)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_lorenzo_delta_exact_inverse(seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.integers(-2**15, 2**15, size=(6, 7, 5)), jnp.int32)
    assert np.array_equal(np.asarray(lorenzo_undelta(lorenzo_delta(q))),
                          np.asarray(q))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000))
def test_zfp_lift_near_inverse(seed):
    """ZFP's integer lifting loses a few LSBs to the arithmetic shifts (it is
    *near*-orthogonal, not bit-exact — zfp itself never relies on exactness
    since coefficients are quantized).  The invariant: fwd∘inv differs by a
    bounded number of lattice steps, tiny relative to the 2^22 magnitudes —
    and the *compressor-level* error bound (test above) absorbs it via the
    correction pass."""
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.integers(-2**22, 2**22, size=(10, 4, 4, 4)), jnp.int32)
    w = v
    for ax in (1, 2, 3):
        w = _fwd_lift(w, ax)
    for ax in (3, 2, 1):
        w = _inv_lift(w, ax)
    assert int(np.abs(np.asarray(w) - np.asarray(v)).max()) <= 64


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.001, 0.3))
def test_outlier_codec_roundtrip(seed, density):
    rng = np.random.default_rng(seed)
    shape = tuple(rng.integers(3, 20, size=3))
    mask = rng.random(shape) < density
    blob = OC.encode_outliers(mask)
    assert np.array_equal(OC.decode_outliers(blob), mask)
    assert blob["packed_bits"] == mask.sum() * OC.coord_bits(shape)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000))
def test_archive_msgpack_roundtrip(seed):
    rng = np.random.default_rng(seed)
    obj = {"a": rng.standard_normal((3, 4)).astype(np.float32),
           "b": {"c": int(rng.integers(0, 100)), "d": [1.5, "x", b"bytes"]},
           "e": rng.integers(0, 100, (5,)).astype(np.int32)}
    back = A.loads(A.dumps(obj))
    assert np.array_equal(back["a"], obj["a"])
    assert np.array_equal(back["e"], obj["e"])
    assert back["b"]["c"] == obj["b"]["c"]
