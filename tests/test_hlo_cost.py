"""The loop-aware HLO analyzer against graphs with known FLOPs."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_plain_matmul_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    c = _compile(lambda a, b: a @ b, a, b)
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] == 2 * 128 * 256 * 64


def test_scan_multiplies_trip_count():
    def g(x, ws):
        def step(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(step, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c = _compile(g, x, ws)
    r = hlo_cost.analyze(c.as_text())
    exp = 12 * 2 * 64 * 64 * 64
    assert 0.95 * exp <= r["flops"] <= 1.3 * exp
    # XLA's own analysis counts the body once - ours must exceed it
    assert r["flops"] > hlo_cost.xla_cost_dict(c).get("flops", 0) * 5


def test_nested_scan():
    def g(x, ws):
        def outer(x, w):
            def inner(x, _):
                return jnp.sin(x) @ w, None
            y, _ = jax.lax.scan(inner, x, None, length=4)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)
    c = _compile(g, x, ws)
    r = hlo_cost.analyze(c.as_text())
    exp = 3 * 4 * 2 * 32 * 32 * 32
    assert 0.9 * exp <= r["flops"] <= 1.6 * exp
