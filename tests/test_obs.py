"""Telemetry subsystem: span trees, counters/gauges, learning traces,
exporters, and the cross-engine timing schema.

Acceptance contract (PR 7): a 3-field snapshot with telemetry enabled on
each engine produces (a) a span tree whose conv/train/write spans nest
correctly and sum to within 10% of ``total_s``, (b) per-field per-epoch
learning traces, (c) valid Chrome ``trace_event`` JSON whose streaming
reader/writer threads overlap compute — and telemetry *disabled* produces
byte-identical archives.
"""
import io
import json

import numpy as np
import pytest

from repro import obs
from repro.core import archive as A
from repro.core import neurlz

ENGINES = ("serial", "batched", "streaming")
EPOCHS = 2

_rng = np.random.default_rng(3)
FIELDS = {f"f{i}": _rng.normal(size=(6, 12, 12)).astype(np.float32)
          for i in range(3)}


def _run(engine, telemetry=None, **kw):
    cfg = neurlz.NeurLZConfig(engine=engine, epochs=EPOCHS,
                              telemetry=telemetry, **kw)
    return neurlz.compress_impl(FIELDS, 1e-3, config=cfg)


@pytest.fixture(scope="module")
def runs():
    """Per engine: (telemetry handle, traced archive, untraced archive)."""
    out = {}
    for engine in ENGINES:
        tel = obs.Telemetry()
        out[engine] = (tel, _run(engine, telemetry=tel), _run(engine))
    return out


def _root(tel):
    roots = [s for s in tel.spans if s.name == "compress"]
    assert len(roots) == 1
    return roots[0]


# ---------------------------------------------------------------------------
# Span tree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_span_tree_nests_under_root(runs, engine):
    tel, _, _ = runs[engine]
    root = _root(tel)
    assert root.parent is None
    ids = {s.id for s in tel.spans}
    for s in tel.spans:
        if s is not root:
            assert s.parent in ids, f"orphan span {s.name}"
    # conv and train happen under the root (directly or via a parent chain)
    by_id = {s.id: s for s in tel.spans}

    def ancestor_of_root(s):
        while s.parent is not None:
            s = by_id[s.parent]
        return s is root

    names = {s.name for s in tel.spans}
    assert {"conv", "train"} <= names
    assert all(ancestor_of_root(s) for s in tel.spans if s is not root)


@pytest.mark.parametrize("engine", ENGINES)
def test_spans_sum_to_root_within_10pct(runs, engine):
    tel, arc, _ = runs[engine]
    root = _root(tel)
    kids = [s for s in tel.spans
            if s.parent == root.id and s.thread == root.thread]
    covered = sum(s.dur for s in kids)
    assert covered >= 0.9 * root.dur, (
        f"{engine}: top-level spans cover {covered:.3f}s of root "
        f"{root.dur:.3f}s")
    assert covered <= root.dur * 1.01
    # the root tracks the engine's own total_s stopwatch
    assert root.dur == pytest.approx(arc["timing"]["total_s"], rel=0.25,
                                     abs=0.25)


def test_streaming_spans_cover_all_threads(runs):
    tel, _, _ = runs["streaming"]
    threads = {s.thread_name for s in tel.spans}
    assert any("writer" in t for t in threads), threads
    assert any("reader" in t for t in threads), threads
    # orphan-thread spans (reader/writer) parent to the root span
    root = _root(tel)
    for s in tel.spans:
        if s.thread != root.thread:
            assert s.parent == root.id


def test_streaming_writer_overlaps_compute(runs):
    tel, _, _ = runs["streaming"]
    root = _root(tel)
    main = [s for s in tel.spans
            if s.thread == root.thread and s is not root]
    other = [s for s in tel.spans if s.thread != root.thread]
    assert other, "no reader/writer-thread spans recorded"

    def overlaps(a, b):
        return a.t0 < b.t0 + b.dur and b.t0 < a.t0 + a.dur

    assert any(overlaps(o, m) for o in other for m in main), (
        "async-thread spans never overlapped main-thread compute")


# ---------------------------------------------------------------------------
# Learning traces
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_learning_traces_one_record_per_epoch(runs, engine):
    tel, _, _ = runs[engine]
    assert sorted(tel.traces) == sorted(FIELDS)
    for name in FIELDS:
        recs = tel.trace(name)
        assert len(recs) == EPOCHS
        assert [r["epoch"] for r in recs] == list(range(EPOCHS))
        for r in recs:
            assert {"loss", "residual_rms", "pred_psnr",
                    "pred_outlier_rate", "pred_bitrate"} <= set(r)
            assert r["loss"] >= 0.0
            assert 0.0 <= r["pred_outlier_rate"] <= 1.0
            assert r["pred_bitrate"] > 0.0


def test_sample_psnr_traces_measured_quality():
    tel = obs.Telemetry(obs.TelemetryConfig(sample_psnr=True,
                                            sample_slices=2))
    _run("serial", telemetry=tel)
    for name in FIELDS:
        recs = tel.trace(name)
        assert all("sample_psnr" in r for r in recs)
        assert all(np.isfinite(r["sample_psnr"]) for r in recs)


def test_sample_psnr_does_not_change_archive():
    tel = obs.Telemetry(obs.TelemetryConfig(sample_psnr=True))
    arc = _run("serial", telemetry=tel)
    arc0 = _run("serial")
    assert A.dumps(arc["fields"]) == A.dumps(arc0["fields"])


# ---------------------------------------------------------------------------
# Disabled path: byte identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_telemetry_disabled_archives_byte_identical(runs, engine):
    _, arc_on, arc_off = runs[engine]
    assert A.dumps(arc_on["fields"]) == A.dumps(arc_off["fields"])


# ---------------------------------------------------------------------------
# Counters / gauges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_conv_counters_match_conv_stage_stats(runs, engine):
    tel, arc, _ = runs[engine]
    cs = arc["timing"]["conv_stage"]
    c = tel.counters
    assert c.get("conv.dispatches", 0) == cs["calls"]
    assert c.get("conv.groups", 0) == cs["groups"]
    assert c.get("conv.batched_fields", 0) == cs["batched_fields"]
    assert c.get("conv.fallback_fields", 0) == cs["fallback_fields"]


def test_streaming_ledger_gauge_and_writer_counters(runs):
    tel, arc, _ = runs["streaming"]
    g = tel.gauges
    assert g["stream.resident_bytes"]["max"] == \
        arc["timing"]["peak_resident_bytes"]
    assert tel.counters["writer.entries"] == len(FIELDS)
    assert tel.counters["stream.evictions"] > 0
    assert "writer.queue_depth" in g


def test_archive_decode_counts_entry_reads(tmp_path):
    from repro.core import archive_api
    from repro.streaming import pipeline
    path = str(tmp_path / "snap.nlzs")
    pipeline.compress(FIELDS, path, 1e-3,
                      config=neurlz.NeurLZConfig(engine="streaming",
                                                 epochs=EPOCHS))
    tel = obs.Telemetry()
    with archive_api.Archive.open(path) as arc:
        arc.telemetry = tel
        arc.decode("f1")
        assert tel.counters["archive.entry_reads"] == \
            len(arc.reader.entry_reads)
        assert tel.counters["archive.entry_reads"] >= 1
        assert any(s.name == "decode" and s.attrs.get("field") == "f1"
                   for s in tel.spans)


# ---------------------------------------------------------------------------
# Cross-engine timing schema (satellite: timing inconsistency fix)
# ---------------------------------------------------------------------------

def test_timing_schema_keys_equal_across_engines(runs):
    keysets = {e: set(runs[e][2]["timing"]) for e in ENGINES}
    for e in ENGINES:
        assert set(obs.TIMING_KEYS) <= keysets[e], e
    assert keysets["serial"] == keysets["batched"]
    # streaming reports the same core schema plus its ledger/writer extras
    assert keysets["serial"] <= keysets["streaming"]


@pytest.mark.parametrize("engine", ENGINES)
def test_enabled_timing_carries_span_summary(runs, engine):
    _, arc, arc_off = runs[engine]
    assert "spans" in arc["timing"]
    assert "spans" not in arc_off["timing"]
    spans = arc["timing"]["spans"]
    assert {"conv", "train"} <= set(spans)
    for agg in spans.values():
        assert agg["count"] >= 1 and agg["wall_s"] >= 0.0


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_is_valid_trace_event_json(runs):
    tel, _, _ = runs["streaming"]
    doc = json.loads(json.dumps(tel.chrome_trace(), default=float))
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert xs, "no complete events"
    for e in xs:
        assert {"name", "cat", "pid", "tid", "ts", "dur"} <= set(e)
    assert len({e["tid"] for e in xs}) >= 3   # main + reader + writer
    names = {e["args"]["name"] for e in events
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert any("writer" in n for n in names)
    # gauge sample trails export as counter tracks
    assert any(e["ph"] == "C" for e in events)


def test_jsonl_export_round_trips(runs):
    tel, _, _ = runs["serial"]
    buf = io.StringIO()
    n = tel.export_jsonl(buf)
    lines = buf.getvalue().splitlines()
    assert len(lines) == n
    recs = [json.loads(ln) for ln in lines]
    assert recs[0]["type"] == "meta"
    kinds = {r["type"] for r in recs}
    assert {"span", "counter", "learning_trace"} <= kinds
    trace_lines = [r for r in recs if r["type"] == "learning_trace"]
    assert len(trace_lines) == len(FIELDS) * EPOCHS


def test_summary_aggregates(runs):
    tel, _, _ = runs["batched"]
    s = tel.summary()
    assert sorted(s["fields"]) == sorted(FIELDS)
    assert s["epochs"] == {n: EPOCHS for n in FIELDS}
    assert s["dropped_spans"] == 0
    assert s["spans"]["compress"]["count"] == 1


# ---------------------------------------------------------------------------
# Handle mechanics
# ---------------------------------------------------------------------------

def test_span_cap_drops_not_grows():
    tel = obs.Telemetry(obs.TelemetryConfig(max_spans=3))
    for i in range(10):
        with tel.span("s", i=i):
            pass
    assert len(tel.spans) == 3
    assert tel.dropped_spans == 7


def test_of_maps_none_to_null():
    cfg = neurlz.NeurLZConfig()
    assert obs.of(cfg) is obs.NULL
    tel = obs.Telemetry()
    cfg = neurlz.NeurLZConfig(telemetry=tel)
    assert obs.of(cfg) is tel


def test_session_api_threads_telemetry(tmp_path):
    import repro
    tel = repro.Telemetry()
    sess = repro.NeurLZ(engine="batched", epochs=EPOCHS, telemetry=tel)
    arc = sess.compress(FIELDS, rel_eb=1e-3)
    assert arc.telemetry is tel
    assert {s.name for s in tel.spans} >= {"compress", "conv", "train"}
    # streaming compress_to attaches the same handle to the lazy Archive
    tel2 = repro.Telemetry()
    sess2 = sess.replace(telemetry=tel2)
    path = str(tmp_path / "s.nlzs")
    with sess2.compress_to(FIELDS, path, rel_eb=1e-3) as arc2:
        assert arc2.telemetry is tel2
        arc2.decode("f0")
        assert tel2.counters["archive.entry_reads"] >= 1
