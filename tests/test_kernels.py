"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("shape", [(8, 16, 16), (24, 40, 33), (16, 8, 128),
                                   (5, 30, 17)])
@pytest.mark.parametrize("eb", [0.1, 1e-3])
def test_lorenzo_fwd_matches_ref(shape, eb):
    x = np.cumsum(RNG.standard_normal(shape), axis=0).astype(np.float32)
    d, rec = ops.lorenzo_quantize(x, eb)
    d_ref, rec_ref = ref.lorenzo3d_fwd_ref(jnp.asarray(x), eb)
    assert np.array_equal(np.asarray(d), np.asarray(d_ref))
    assert np.allclose(np.asarray(rec), np.asarray(rec_ref))


@pytest.mark.parametrize("shape", [(8, 16, 16), (12, 24, 20)])
def test_lorenzo_inverse_roundtrip(shape):
    eb = 0.01
    x = np.cumsum(RNG.standard_normal(shape), axis=1).astype(np.float32)
    d, rec = ops.lorenzo_quantize(x, eb)
    q = ops.lorenzo_dequantize(d, eb)
    # inverse reproduces the fused-kernel reconstruction
    assert np.allclose(np.asarray(q), np.asarray(rec), atol=1e-6)
    assert np.abs(np.asarray(q) - x).max() <= eb * (1 + 1e-6)


@pytest.mark.parametrize("shape", [(4, 16, 16), (16, 40, 33)])
@pytest.mark.parametrize("mode", [(True, True), (True, False), (False, False)])
def test_fused_enhance_matches_ref(shape, mode):
    regulated, strict = mode
    eb = 0.05
    z = RNG.standard_normal(shape).astype(np.float32)
    dec = RNG.standard_normal(shape).astype(np.float32)
    orig = (dec + RNG.uniform(-eb, eb, shape)).astype(np.float32)
    out, mask = ops.enhance(z, dec, orig, eb, regulated=regulated, strict=strict)
    out_r, mask_r = ref.fused_enhance_ref(jnp.asarray(z), jnp.asarray(dec),
                                          jnp.asarray(orig), eb,
                                          regulated=regulated, strict=strict)
    # 1-ulp differences possible (sigmoid fusion); mask knife-edges likewise
    assert np.allclose(np.asarray(out), np.asarray(out_r), rtol=2e-5, atol=1e-6)
    assert (np.asarray(mask) != np.asarray(mask_r)).mean() < 1e-2


def test_fused_enhance_strict_bound():
    eb = 0.05
    shape = (8, 32, 32)
    z = RNG.standard_normal(shape).astype(np.float32) * 5
    dec = RNG.standard_normal(shape).astype(np.float32)
    orig = (dec + RNG.uniform(-eb, eb, shape)).astype(np.float32)
    out, _ = ops.enhance(z, dec, orig, eb, regulated=True, strict=True)
    assert np.abs(np.asarray(out) - orig).max() <= eb * (1 + 1e-5)


@pytest.mark.parametrize("hw", [(16, 16), (24, 20), (25, 33), (31, 17)])
@pytest.mark.parametrize("cin,cout", [(1, 4), (4, 6), (8, 8), (12, 4)])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv3x3_sweep(hw, cin, cout, stride):
    h, w_ = hw
    x = RNG.standard_normal((2, h, w_, cin)).astype(np.float32)
    w = (RNG.standard_normal((3, 3, cin, cout)) * 0.2).astype(np.float32)
    b = (RNG.standard_normal((cout,)) * 0.1).astype(np.float32)
    y = ops.conv3x3(x, w, b, stride=stride)
    yr = ref.conv2d3x3_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                           stride=stride)
    assert y.shape == yr.shape
    assert np.allclose(np.asarray(y), np.asarray(yr), atol=1e-5)


# ---------------------------------------------------------------------------
# Oracle parity matrix: every kernel x dtype x shape-class (x regulation
# mode where it applies), each cell checked against the ref.py oracle.
# "even" shapes hit the aligned fast path, "odd"/"ragged" (prime extents)
# force the wrappers' explicit pad/crop.
# ---------------------------------------------------------------------------

SHAPES3D = {"even": (8, 16, 16), "odd": (7, 15, 33), "ragged": (17, 9, 11)}
DTYPES = [np.float32, np.float64]


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "f64"])
@pytest.mark.parametrize("cls", sorted(SHAPES3D))
def test_parity_matrix_lorenzo(cls, dtype):
    shape = SHAPES3D[cls]
    x = np.cumsum(RNG.standard_normal(shape), axis=0).astype(dtype)
    d, rec = ops.lorenzo_quantize(x, 1e-2)
    assert d.shape == shape and rec.shape == shape
    x32 = jnp.asarray(x.astype(np.float32))   # kernel computes in fp32
    d_r, rec_r = ref.lorenzo3d_fwd_ref(x32, 1e-2)
    assert np.array_equal(np.asarray(d), np.asarray(d_r))
    assert np.allclose(np.asarray(rec), np.asarray(rec_r), atol=1e-6)
    q = ops.lorenzo_dequantize(d, 1e-2)
    q_r = ref.lorenzo3d_inv_ref(d_r).astype(jnp.float32) * (2.0 * 1e-2)
    assert q.shape == shape
    assert np.allclose(np.asarray(q), np.asarray(q_r), atol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "f64"])
@pytest.mark.parametrize("cls", sorted(SHAPES3D))
@pytest.mark.parametrize("strict", [True, False], ids=["strict", "relaxed"])
def test_parity_matrix_enhance(cls, dtype, strict):
    shape = SHAPES3D[cls]
    eb = 0.05
    z = RNG.standard_normal(shape).astype(np.float32)
    dec = RNG.standard_normal(shape).astype(dtype)
    orig = (dec + RNG.uniform(-eb, eb, shape)).astype(dtype)
    out, mask = ops.enhance(z, dec, orig, eb, regulated=True, strict=strict)
    assert out.shape == shape and mask.shape == shape
    out_r, mask_r = ref.fused_enhance_ref(jnp.asarray(z), jnp.asarray(dec),
                                          jnp.asarray(orig), eb,
                                          regulated=True, strict=strict)
    assert np.allclose(np.asarray(out), np.asarray(out_r),
                       rtol=2e-5, atol=1e-6)
    assert (np.asarray(mask) != np.asarray(mask_r)).mean() < 1e-2


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "f64"])
@pytest.mark.parametrize("cls,hw,cout", [("even", (16, 16), 4),
                                         ("odd", (15, 33), 6),
                                         ("ragged", (17, 11), 1)])
def test_parity_matrix_conv3x3(cls, hw, cout, dtype):
    h, w_ = hw
    x = RNG.standard_normal((2, h, w_, 4)).astype(dtype)
    w = (RNG.standard_normal((3, 3, 4, cout)) * 0.2).astype(np.float32)
    b = (RNG.standard_normal((cout,)) * 0.1).astype(np.float32)
    y = ops.conv3x3(x, w, b, relu=False)
    yr = ref.conv2d3x3_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                           relu=False)
    assert y.shape == yr.shape == (2, h, w_, cout)
    assert np.allclose(np.asarray(y), np.asarray(yr), atol=1e-5)


# ---------------------------------------------------------------------------
# Pad/crop regressions: non-multiple shapes must engage a real tile (not
# degrade to tile=1) and crop back exactly.
# ---------------------------------------------------------------------------

def test_pick_tz_ragged_depth_uses_real_tile():
    # Prime depth: before the pad/crop fix this degraded to tz=1 (one grid
    # step per plane); now the largest fitting slab is chosen and the depth
    # is padded up to it.
    assert ops._pick_tz(17, 16, 16) > 1
    assert ops._pick_tz(1, 16, 16) == 1   # never exceeds the depth


def test_lorenzo_pad_crop_regression():
    eb = 1e-2
    x = np.cumsum(RNG.standard_normal((17, 9, 11)), axis=0).astype(np.float32)
    d, rec = ops.lorenzo_quantize(x, eb)
    # aligned reference computation: pad manually to the tile, crop after
    d_a, rec_a = ref.lorenzo3d_fwd_ref(jnp.asarray(x), eb)
    assert np.array_equal(np.asarray(d), np.asarray(d_a))
    assert np.array_equal(np.asarray(rec), np.asarray(rec_a))
    q = ops.lorenzo_dequantize(d, eb)
    assert q.shape == x.shape
    assert np.abs(np.asarray(q) - x).max() <= eb * (1 + 1e-6)


def test_enhance_pad_crop_regression():
    eb = 0.02
    shape = (7, 13, 5)   # rows = 91 (prime-ish): engages the row pad
    z = RNG.standard_normal(shape).astype(np.float32)
    dec = RNG.standard_normal(shape).astype(np.float32)
    orig = (dec + RNG.uniform(-eb, eb, shape)).astype(np.float32)
    out, mask = ops.enhance(z, dec, orig, eb)
    out_r, mask_r = ref.fused_enhance_ref(jnp.asarray(z), jnp.asarray(dec),
                                          jnp.asarray(orig), eb)
    assert out.shape == mask.shape == shape
    assert np.allclose(np.asarray(out), np.asarray(out_r),
                       rtol=2e-5, atol=1e-6)
    assert np.array_equal(np.asarray(mask), np.asarray(mask_r))


def test_conv3x3_odd_cout_pad_crop_regression():
    # C_out=1 (the network head): padded to an even GEMM shape and cropped.
    x = RNG.standard_normal((3, 10, 12, 4)).astype(np.float32)
    w = (RNG.standard_normal((3, 3, 4, 1)) * 0.2).astype(np.float32)
    b = np.zeros((1,), np.float32)
    y = ops.conv3x3(x, w, b, relu=False)
    yr = ref.conv2d3x3_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                           relu=False)
    assert y.shape == (3, 10, 12, 1)
    assert np.allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
