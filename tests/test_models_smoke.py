"""Reduced-config smoke tests: one train step + one decode step per arch,
asserting output shapes and finiteness (full configs only via dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step(arch):
    cfg = configs.get_reduced(arch)
    model = M.build_model(cfg, model_axis=1)
    params, opt = M.init_train_state(model)
    batch = M.demo_batch(cfg, batch=2, seq=32)
    step = jax.jit(M.make_train_step(model, lr=1e-3))
    p2, o2, metrics = step(params, opt, batch, jnp.zeros((), jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), params, p2))
    assert max(moved) > 0


@pytest.mark.parametrize("arch", [a for a in configs.ARCHS
                                  if configs.get_config(a).family != "audio"])
def test_decode_step(arch):
    cfg = configs.get_reduced(arch)
    model = M.build_model(cfg, model_axis=1)
    params = M.init_params(model)
    cache = model.init_cache(batch=2, max_len=32)
    step = jax.jit(M.make_decode_step(model))
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = step(params, cache, toks, jnp.asarray(0, jnp.int32))
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert np.isfinite(np.asarray(logits)).all()
    # cache must be updated in place structurally
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_microbatched_grad_matches_single(arch):
    """Gradient accumulation == full-batch gradient (linearity check)."""
    cfg = configs.get_reduced(arch)
    model = M.build_model(cfg, model_axis=1)
    params, opt = M.init_train_state(model)
    batch = M.demo_batch(cfg, batch=4, seq=16)
    s1 = jax.jit(M.make_train_step(model, lr=1e-3, microbatch=1))
    s2 = jax.jit(M.make_train_step(model, lr=1e-3, microbatch=2))
    _, _, m1 = s1(params, opt, batch, jnp.zeros((), jnp.int32))
    _, _, m2 = s2(params, opt, batch, jnp.zeros((), jnp.int32))
    # f32 accumulation-order tolerance: hubert's conv feature extractor
    # drifts up to ~7e-2 between microbatch splits on CPU.
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 8e-2


def test_decode_matches_forward_dense():
    """Teacher-forced decode logits == training forward logits (qwen3)."""
    cfg = configs.get_reduced("qwen3-4b")
    model = M.build_model(cfg, model_axis=1)
    params = M.init_params(model)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    hidden = model.forward(params, {"tokens": toks})
    from repro.models.layers import rmsnorm
    h = rmsnorm(hidden, params["ln_f"], cfg.norm_eps)
    full_logits = np.asarray(model._logits(params, h).astype(jnp.float32))

    cache = model.init_cache(1, 8)
    step = jax.jit(M.make_decode_step(model))
    for pos in range(8):
        logits, cache = step(params, cache, toks[:, pos:pos + 1],
                             jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits)[0, 0],
                                   full_logits[0, pos], rtol=2e-2, atol=2e-2)
