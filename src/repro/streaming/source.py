"""Chunked field sources: lazy, re-loadable snapshot inputs.

A :class:`ChunkedFieldSource` describes a snapshot whose fields may not fit
in host memory at once.  It exposes *metadata* for every field up front
(``names`` / ``meta`` — enough for the scheduler to plan groups and budget
residency without touching data) and loads field arrays lazily via
``load``.  ``load`` may be called more than once for the same field: the
pipeline evicts originals after their group finalizes and reloads an
aux-producer's original only if its own group runs later, so sources must
be re-loadable (a dict lookup, a memmap'd ``.npy`` read, or a deterministic
generator re-run — all three are provided here).

:class:`BlockedSource` additionally splits huge fields into spatial blocks
along the slice axis, so a single field larger than the residency budget
still streams through the engine block by block; its ``manifest`` rides in
the archive footer and lets the streaming decoder reassemble full fields.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Iterable, Mapping, Protocol, runtime_checkable

import numpy as np


@dataclasses.dataclass(frozen=True)
class FieldMeta:
    shape: tuple
    dtype: np.dtype
    nbytes: int

    @classmethod
    def of(cls, shape, dtype) -> "FieldMeta":
        shape = tuple(int(s) for s in shape)
        dtype = np.dtype(dtype)
        return cls(shape=shape, dtype=dtype,
                   nbytes=int(np.prod(shape)) * dtype.itemsize)


@runtime_checkable
class ChunkedFieldSource(Protocol):
    def names(self) -> list[str]:
        """Field names in snapshot order (the archive's field order)."""

    def meta(self, name: str) -> FieldMeta:
        """Shape/dtype/nbytes without loading the data."""

    def load(self, name: str) -> np.ndarray:
        """Materialize one field.  Must be callable repeatedly."""


class DictSource:
    """In-memory mapping of arrays (the classic ``compress`` input)."""

    def __init__(self, fields: Mapping[str, np.ndarray]):
        self._fields = fields

    def names(self) -> list[str]:
        return list(self._fields)

    def meta(self, name: str) -> FieldMeta:
        x = self._fields[name]
        if not hasattr(x, "dtype"):
            x = np.asarray(x)
        return FieldMeta.of(x.shape, x.dtype)

    def load(self, name: str) -> np.ndarray:
        return np.asarray(self._fields[name])


class FunctionSource:
    """Generator-backed source: fields materialize on demand from a
    callable (e.g. a simulation snapshot reader or a synthetic generator).

    ``metas`` maps name -> (shape, dtype); ``loader(name)`` must be
    deterministic so repeated loads yield the same bytes.
    """

    def __init__(self, metas: Mapping[str, tuple],
                 loader: Callable[[str], np.ndarray]):
        self._metas = {n: FieldMeta.of(shape, dtype)
                       for n, (shape, dtype) in metas.items()}
        self._loader = loader

    def names(self) -> list[str]:
        return list(self._metas)

    def meta(self, name: str) -> FieldMeta:
        return self._metas[name]

    def load(self, name: str) -> np.ndarray:
        return np.asarray(self._loader(name))


class NpyDirSource:
    """A directory of ``<field>.npy`` files, opened as memmaps so ``load``
    itself costs no resident memory until slices are actually read."""

    def __init__(self, path: str, names: Iterable[str] | None = None):
        self._dir = path
        if names is None:
            names = sorted(f[:-4] for f in os.listdir(path)
                           if f.endswith(".npy"))
        self._names = list(names)

    def _path(self, name: str) -> str:
        return os.path.join(self._dir, f"{name}.npy")

    def names(self) -> list[str]:
        return self._names

    def meta(self, name: str) -> FieldMeta:
        m = np.load(self._path(name), mmap_mode="r")
        return FieldMeta.of(m.shape, m.dtype)

    def load(self, name: str) -> np.ndarray:
        return np.load(self._path(name), mmap_mode="r")


class BlockedSource:
    """Split fields bigger than ``max_block_bytes`` into spatial blocks
    along ``slice_axis``.

    Blocks appear as virtual fields named ``{name}#b{i}`` and compress as
    independent entries (each with its own normalization stats and error
    bound, exactly as if the caller had pre-split the snapshot), so a field
    larger than the residency budget still streams through.  ``manifest``
    maps each split field to its ordered ``(block_name, lo, hi)`` spans;
    the streaming decoder uses it to reassemble full fields.
    """

    def __init__(self, base: ChunkedFieldSource, max_block_bytes: int,
                 slice_axis: int = 0):
        self._base = base
        self._axis = slice_axis
        self._metas: dict[str, FieldMeta] = {}
        self._spans: dict[str, tuple[str, int, int]] = {}
        self.manifest: dict[str, list] = {}
        for name in base.names():
            m = base.meta(name)
            axis = slice_axis % len(m.shape)
            n_slices = m.shape[axis]
            slice_bytes = max(1, m.nbytes // n_slices)
            per_block = min(n_slices,
                            max(1, int(max_block_bytes) // slice_bytes))
            if max_block_bytes <= 0 or per_block >= n_slices:
                self._metas[name] = m
                continue
            spans = []
            for bi, lo in enumerate(range(0, n_slices, per_block)):
                hi = min(lo + per_block, n_slices)
                bname = f"{name}#b{bi}"
                shape = tuple(hi - lo if i == axis else s
                              for i, s in enumerate(m.shape))
                self._metas[bname] = FieldMeta.of(shape, m.dtype)
                self._spans[bname] = (name, lo, hi)
                spans.append([bname, lo, hi])
            self.manifest[name] = {"axis": axis, "blocks": spans}

    def names(self) -> list[str]:
        return list(self._metas)

    def meta(self, name: str) -> FieldMeta:
        return self._metas[name]

    def load(self, name: str) -> np.ndarray:
        if name not in self._spans:
            return self._base.load(name)
        base_name, lo, hi = self._spans[name]
        axis = self.manifest[base_name]["axis"]
        x = self._base.load(base_name)
        idx = tuple(slice(lo, hi) if i == axis else slice(None)
                    for i in range(x.ndim))
        return np.ascontiguousarray(x[idx])


def synthetic_snapshot_source(num_fields: int, shape=(16, 32, 32),
                              dataset: str = "nyx", seed0: int = 2
                              ) -> FunctionSource:
    """Lazy synthetic snapshot matching ``benchmarks.common.snapshot_fields``
    naming — each field regenerates only its own seed block on ``load``, so
    snapshots far larger than memory can be produced for testing."""
    from ..data import fields as F

    specs = F.snapshot_specs(num_fields, shape=shape, dataset=dataset,
                             seed0=seed0)
    dtype = F.DATASET_DTYPES[dataset]
    metas = {name: (spec["shape"], dtype) for name, spec in specs.items()}
    return FunctionSource(metas, lambda name: F.load_spec(specs[name]))


def as_source(obj) -> ChunkedFieldSource:
    """Coerce compress inputs: mapping -> DictSource, dir path ->
    NpyDirSource, sources pass through."""
    if isinstance(obj, (DictSource, FunctionSource, NpyDirSource,
                        BlockedSource)):
        return obj
    if isinstance(obj, Mapping):
        return DictSource(obj)
    if isinstance(obj, (str, os.PathLike)) and os.path.isdir(obj):
        return NpyDirSource(os.fspath(obj))
    if isinstance(obj, ChunkedFieldSource):
        return obj
    raise TypeError(f"cannot interpret {type(obj)} as a ChunkedFieldSource")
