"""End-to-end behaviour of the full system: the paper's pipeline on
multi-field scientific data + the training framework around it."""
import numpy as np

from repro import core
from repro.core import metrics
from repro.data import fields as F


def test_paper_claim_residual_learning_beats_direct():
    """Paper Fig. 4 (left): learning the residual R = X - X' beats learning
    X directly (training stability at large value ranges)."""
    flds = F.make_fields("nyx", shape=(24, 40, 40), seed=5)
    sub = {"temperature": flds["temperature"]}
    psnrs = {}
    for residual in (True, False):
        cfg = core.NeurLZConfig(epochs=4, mode="unregulated",
                                learn_residual=residual)
        arc = core.compress(sub, rel_eb=1e-2, config=cfg)
        dec = core.decompress(arc)
        psnrs[residual] = metrics.psnr(sub["temperature"], dec["temperature"])
    assert psnrs[True] > psnrs[False], psnrs


def test_paper_claim_bitrate_reduction_positive_at_loose_bounds():
    """At loose bounds NeurLZ must beat the conventional compressor at equal
    PSNR (Table 2 direction; magnitudes are dataset-specific)."""
    import repro.compressors as C

    flds = F.make_fields("nyx", shape=(32, 48, 48), seed=2)
    x = flds["dark_matter_density"]
    cfg = core.NeurLZConfig(epochs=20, mode="relaxed")
    arc = core.compress({"f": x}, rel_eb=1e-2, config=cfg)
    dec = core.decompress(arc)["f"]
    p_nlz = metrics.psnr(x, dec)
    br = arc["bitrate"]["f"]
    # paper accounting: enhancer weights amortize over 512^3 runtime blocks
    br_nlz = 8.0 * (br["conv_bytes"] + br["outlier_bytes"]
                    + br["weight_bytes"] * x.size / 512**3) / x.size

    # conventional rate-distortion curve around the same PSNR
    pts = []
    for eb in (2e-2, 1e-2, 5e-3, 2e-3, 1e-3):
        a, _ = C.compress(x, eb, compressor="szlike")
        d = C.decompress(a)
        pts.append((metrics.psnr(x, d), 8.0 * a["nbytes"] / x.size))
    pts.sort()
    psnrs = [p for p, _ in pts]
    brs = [b for _, b in pts]
    br_conv = float(np.interp(p_nlz, psnrs, brs))
    # positive reduction at the paper's weight-amortization operating point
    assert br_nlz < br_conv, (br_nlz, br_conv, p_nlz)


def test_trainer_end_to_end_loss_decreases(tmp_path):
    from types import SimpleNamespace

    from repro.launch.train import train

    args = SimpleNamespace(
        arch="qwen3-4b", preset="reduced", steps=10, batch=4, seq=64,
        lr=3e-3, seed=0, microbatch=1, ckpt_dir=str(tmp_path),
        ckpt_every=5, keep=2, resume=True, lossy_ckpt_eb=None,
        fail_at_step=None, step_deadline=300.0, log_every=0)
    report = train(args)
    assert report["last_loss"] < report["first_loss"]
    assert report["watchdog"]["steps"] == 10


def test_serve_end_to_end(capsys):
    from types import SimpleNamespace

    from repro.launch.serve import serve

    args = SimpleNamespace(arch="gemma-2b", batch=2, prompt_len=16, gen=8,
                           seed=0)
    report = serve(args)
    assert report["generated"] == 8
    assert report["decode_tok_per_s"] > 0
