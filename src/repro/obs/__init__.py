"""Observability for the NeurLZ engines: spans, counters, learning traces.

Usage::

    import repro
    from repro import obs

    tel = obs.Telemetry()
    sess = repro.NeurLZ(engine="streaming", telemetry=tel)
    sess.compress_to(fields, "snap.nlzs", rel_eb=1e-3)

    tel.export_chrome_trace("trace.json")   # flame graph in Perfetto
    tel.export_jsonl("events.jsonl")        # line-per-event log
    tel.summary()                           # aggregated dict
    tel.trace("temperature")                # per-epoch learning trajectory

Pass no telemetry (the default) and every instrumentation point degrades to
a shared no-op singleton — the disabled path allocates nothing and archives
are byte-identical to an uninstrumented run.

This package imports neither jax nor ``repro.core`` — creating a handle
never flips the x64 switch or pays an engine import.
"""
from .telemetry import (NULL, TIMING_KEYS, Counter, Gauge,  # noqa: F401
                        NullTelemetry, SpanRecord, Telemetry,
                        TelemetryConfig, build_timing, learning_trace, of)
from .export import (chrome_trace, summary, write_chrome_trace,  # noqa: F401
                     write_jsonl)

__all__ = [
    "Telemetry", "TelemetryConfig", "NullTelemetry", "NULL", "of",
    "Counter", "Gauge", "SpanRecord", "TIMING_KEYS",
    "build_timing", "learning_trace",
    "write_jsonl", "chrome_trace", "write_chrome_trace", "summary",
]
