"""Logical-axis sharding rules: param/cache/input pytrees -> PartitionSpecs.

Conventions (see ``repro.models.layers``):
  * ``*_in``   [d_in, d_out], d_out tensor-parallel      -> P(fsdp, tp)
  * ``*_out``  [d_in, d_out], d_in  tensor-parallel      -> P(tp, fsdp)
  * ``embed``  [vocab, d]                                 -> P(tp, fsdp)
  * ``w_experts_{gate,up}`` [E, d, f]  (expert parallel)  -> P(tp, fsdp, ·)
  * ``w_experts_down``      [E, f, d]                     -> P(tp, ·, fsdp)
  * 1-D scales/biases                                     -> replicated

Rules apply to the TRAILING dims; leading stack dims (scan-over-layers /
unit stacking) are always unsharded.  Every dim is guarded by a
divisibility check — a dim that doesn't divide its mesh axis is replicated
rather than failing, so one rule set serves every arch (e.g. gemma3's 4 KV
heads on a 16-way model axis fall back to head-dim sharding in the cache
rules below).

The multi-pod design: weights are FSDP-sharded *within* a pod (``data``)
and replicated *across* pods; the batch spans ("pod", "data").  Cross-pod
traffic is therefore exactly the gradient all-reduce — the target of the
compressed grad-sync optimization.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _guard(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Replicate any dim that doesn't divide its mesh axis; trim/extend."""
    spec = (None,) * (len(shape) - len(spec)) + tuple(spec[-len(shape):] if spec else ())
    out = []
    for dim, ax in zip(shape, spec):
        out.append(ax if ax is not None and dim % _axis_size(mesh, ax) == 0 else None)
    return P(*out)


# trailing-name -> trailing-dims spec (applied to the last len(spec) dims)
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("model", "data")),
    (r"w_experts_(gate|up)$", ("model", "data", None)),
    (r"w_experts_down$", ("model", None, "data")),
    (r"r_gates$", ("model", None, None)),
    (r"conv_w$", (None, "model")),
    (r".*_in$", ("data", "model")),
    (r".*_out$", ("model", "data")),
]

_CACHE_RULES: list[tuple[str, tuple, tuple]] = [
    # (name, primary trailing spec, fallback trailing spec)
    (r"^(k|v)$", ("batch", None, "model", None), ("batch", None, None, "model")),
    (r"^state$", ("batch", "model", None, None), ("batch", None, None, None)),
    (r"^conv$", ("batch", None, "model"), ("batch", None, None)),
    (r"^S$", ("batch", "model", None, None), ("batch", None, None, None)),
    (r"^(n|c|h)$", ("batch", "model", None), ("batch", None, None)),
    (r"^m$", ("batch", "model"), ("batch", None)),
]

BATCH_AXES = ("pod", "data")


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspecs(abstract_params, mesh: Mesh):
    """PartitionSpec tree for a param pytree (by path-name rules)."""

    def assign(path, leaf):
        name = _path_str(path).split("/")[-1]
        shape = leaf.shape
        if len(shape) <= 1:
            return P()
        for pat, spec in _PARAM_RULES:
            if re.search(pat, name):
                return _guard(spec, shape, mesh)
        return P()  # replicate anything unmatched

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


def batch_axes_for(mesh: Mesh, batch_size: int):
    """Largest batch sharding the mesh supports for this batch size."""
    full = tuple(a for a in BATCH_AXES if a in mesh.shape)
    if full and batch_size % _axis_size(mesh, full) == 0:
        return full
    for a in reversed(full):
        if batch_size % _axis_size(mesh, (a,)) == 0:
            return (a,)
    return None


def cache_pspecs(abstract_cache, mesh: Mesh, batch_size: int):
    batch = batch_axes_for(mesh, batch_size)

    def assign(path, leaf):
        name = _path_str(path).split("/")[-1]
        for pat, spec, fallback in _CACHE_RULES:
            if re.search(pat, name):
                primary = list(batch if a == "batch" else a for a in spec)
                fb = list(batch if a == "batch" else a for a in fallback)
                # Long-context decode with unshardable batch (e.g. B=1 at
                # 500k): sequence-parallel KV cache over the data axis.
                if re.match(r"^(k|v)$", name) and batch is None:
                    primary[1] = "data"
                    fb[1] = "data"
                cand = _guard(tuple(primary), leaf.shape, mesh)
                # If the model-parallel dim was dropped by the guard, try the
                # fallback (e.g. shard head_dim when KV heads don't divide).
                if "model" in spec and "model" not in cand:
                    return _guard(tuple(fb), leaf.shape, mesh)
                return cand
        return P()

    return jax.tree_util.tree_map_with_path(assign, abstract_cache)


def input_pspecs(specs: dict, mesh: Mesh, *, seq_shard: bool = False):
    """Input batch shardings: batch over (pod, data); optional SP on seq."""

    def assign(name, leaf):
        batch = batch_axes_for(mesh, leaf.shape[0])
        rest = [None] * (len(leaf.shape) - 1)
        if seq_shard and len(leaf.shape) >= 2 and leaf.shape[1] % _axis_size(mesh, "model") == 0:
            rest[0] = "model"
        return P(batch, *rest)

    return {k: assign(k, v) for k, v in specs.items()}


def opt_pspecs(param_specs):
    """AdamW state: moments follow the params; step is replicated."""
    from ..optim.adamw import AdamWState

    return AdamWState(step=P(), mu=param_specs,
                      nu=jax.tree.map(lambda s: s, param_specs))


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree_specs, is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation sharding constraints (anchor SPMD propagation inside scans).
#
# Model code calls ``constrain(x, ("batch", None, "model"))`` at key points
# (embedding output, q/k/v, MLP hidden).  Without these anchors the
# partitioner replicates the flash-attention inner loops — measured 256× FLOP
# waste on the first dry-run (see EXPERIMENTS.md §Perf iteration 0).
# ---------------------------------------------------------------------------

_ACTIVE_MESH: Mesh | None = None

# ---------------------------------------------------------------------------
# Field-axis sharding for the batched NeurLZ compression engine.
#
# The engine stacks per-field enhancer params/slices on a leading "field"
# axis (``repro.core.skipping_dnn.stack_params``); placing that axis on a
# 1-D device mesh makes each device train its own subset of a snapshot's
# fields — enhancers are independent, so no collectives are needed until the
# host gathers trained weights for the archive.
# ---------------------------------------------------------------------------

FIELD_AXIS = "field"


def field_mesh(devices=None) -> Mesh | None:
    """1-D mesh over the field axis; ``None`` on a single-device process
    (where sharding would only add dispatch overhead)."""
    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) <= 1:
        return None
    return Mesh(np.array(devs), (FIELD_AXIS,))


def field_sharding(mesh: Mesh, num_fields: int) -> NamedSharding:
    """NamedSharding for a leading-``F``-axis array, guarded: a field count
    that doesn't divide the mesh replicates instead of failing."""
    ax = FIELD_AXIS if num_fields % _axis_size(mesh, FIELD_AXIS) == 0 else None
    return NamedSharding(mesh, P(ax))


def shard_fields(tree, mesh: Mesh):
    """device_put every leading-``F``-axis leaf of a stacked pytree."""
    def put(leaf):
        return jax.device_put(leaf, field_sharding(mesh, leaf.shape[0]))
    return jax.tree.map(put, tree)


def set_active_mesh(mesh: Mesh | None):
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def active_mesh():
    return _ACTIVE_MESH


def constrain(x, spec: tuple):
    """Apply a guarded with_sharding_constraint; no-op without a mesh.

    ``"batch"`` resolves to the (pod, data) axes that divide the dim;
    any other axis name is kept only if the dim divides it.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    resolved = []
    for dim, ax in zip(x.shape, spec):
        if ax == "batch":
            ax = batch_axes_for(mesh, dim)
        if ax is None:
            resolved.append(None)
        elif dim % _axis_size(mesh, ax) == 0:
            resolved.append(ax)
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
