"""Error regulation (§3.3): strict 1× control and relaxed 2× regulation.

* strict   — enhanced points whose error exceeds ``eb`` are outliers; their
  coordinates are stored (``repro.compressors.outliers``) and they are
  replaced by the decompressed value at decode time — which is in-bound by
  the conventional compressor's guarantee, so the 1× bound holds everywhere.
* relaxed  — no outlier storage; the regulated Sigmoid head already caps the
  added residual at ``±eb`` so the worst case is ``2×eb`` (Fig. 6 Case B).
* unregulated — linear head, no guarantee (paper ablation; better PSNR,
  worse MAE/DSSIM tails).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import dispatch

MODES = ("strict", "relaxed", "unregulated")


def enhance(decomp: np.ndarray, resid_norm: np.ndarray, eb: float,
            out_dtype=None) -> np.ndarray:
    """X̂ = X' + R̂ where R̂ = resid_norm * eb (resid_norm from the DNN)."""
    out_dtype = out_dtype or decomp.dtype
    enh = decomp.astype(np.float64) + resid_norm.astype(np.float64) * eb
    return enh.astype(out_dtype)


def outlier_mask(orig: np.ndarray, enhanced: np.ndarray, eb: float) -> np.ndarray:
    """Points where the *final-dtype* enhanced value violates the 1× bound."""
    err = np.abs(enhanced.astype(np.float64) - orig.astype(np.float64))
    return err > eb


def apply_strict(enhanced: np.ndarray, decomp: np.ndarray,
                 mask: np.ndarray) -> np.ndarray:
    """Replace outliers with the in-bound decompressed values (Fig. 5)."""
    out = enhanced.copy()
    out[mask] = decomp[mask]
    return out


# --------------------------------------------------------------------------
# fused enhance + regulate dispatch op
#
# The eager reference is the float64 numpy sequence above (enhance →
# outlier_mask → apply_strict).  The jit variant mirrors it in jnp with an
# ``optimization_barrier`` between the multiply and the add so XLA cannot
# FMA-contract the widened arithmetic; with x64 enabled (the package enables
# it for FP64 datasets) the mirror is byte-identical and its parity probe
# passes.  If a host runs with x64 disabled (``launch.dryrun`` turns it off),
# the "wide" arithmetic narrows to float32, the double-rounding canary trips
# the probe, and the dispatcher falls back to eager — the honest-fallback
# case the bit-stability contract is built around: a lowering that cannot
# prove byte-identity never runs.  The pallas variant wraps the fused TPU
# kernel (kernels.ops.enhance) and is gated to TPU backends + its own probe.
# --------------------------------------------------------------------------


def fused_enhance(decomp: np.ndarray, resid_norm: np.ndarray,
                  orig: np.ndarray, eb: float, *, out_dtype=None,
                  mode: str = "strict"):
    """Enhance + regulate in one step: ``(field_rec, mask_or_None)``.

    Eager reference for the ``fused_enhance`` dispatch op; byte-identical to
    calling :func:`enhance` / :func:`outlier_mask` / :func:`apply_strict`
    in sequence.
    """
    enh = enhance(decomp, resid_norm, eb, out_dtype)
    if mode == "strict":
        mask = outlier_mask(orig, enh, eb)
        return apply_strict(enh, decomp, mask), mask
    return enh, None


@functools.partial(jax.jit, static_argnames=("out_dtype", "mode"))
def _fused_enhance_jit_core(decomp, resid_norm, orig, eb, *, out_dtype, mode):
    wide = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    prod = jax.lax.optimization_barrier(resid_norm.astype(wide)
                                        * eb.astype(wide))
    enh = (decomp.astype(wide) + prod).astype(out_dtype)
    if mode != "strict":
        return enh, None
    err = jnp.abs(enh.astype(wide) - orig.astype(wide))
    mask = err > eb.astype(wide)
    return jnp.where(mask, decomp, enh), mask


def _fused_enhance_jit(decomp, resid_norm, orig, eb, *, out_dtype=None,
                       mode: str = "strict"):
    out_dtype = np.dtype(out_dtype or decomp.dtype)
    enh, mask = _fused_enhance_jit_core(
        jnp.asarray(decomp), jnp.asarray(resid_norm), jnp.asarray(orig),
        jnp.asarray(eb), out_dtype=out_dtype.name, mode=mode)
    return np.asarray(enh), None if mask is None else np.asarray(mask)


def _fused_enhance_pallas(decomp, resid_norm, orig, eb, *, out_dtype=None,
                          mode: str = "strict"):
    from ..kernels import ops as kernel_ops
    out_dtype = np.dtype(out_dtype or decomp.dtype)
    # z is the already-regulated residual in [-1, 1]; regulated=False makes
    # the kernel use it as-is (resid = z * eb).
    enh, bad = kernel_ops.enhance(jnp.asarray(resid_norm),
                                  jnp.asarray(decomp), jnp.asarray(orig),
                                  float(eb), regulated=False,
                                  strict=(mode == "strict"))
    enh = np.asarray(enh).astype(out_dtype)
    if mode != "strict":
        return enh, None
    mask = np.asarray(bad).astype(bool)
    dec = np.asarray(decomp)
    out = enh.copy()
    out[mask] = dec[mask]
    return out, mask


def _enhance_canaries():
    """Adversarial inputs: double-rounding boundary + bound-edge outliers."""
    rng = np.random.default_rng(7)
    decomp = rng.standard_normal((3, 5, 7)).astype(np.float32)
    resid = np.clip(rng.standard_normal((3, 5, 7)), -1, 1).astype(np.float32)
    orig = (decomp + resid * 1e-2 * rng.choice([0.5, 1.5], (3, 5, 7))
            ).astype(np.float32)
    # float64 add of (1, 2**-24 + 2**-48) rounds to 1 + 2**-23 after the
    # float32 cast; a float32 add rounds the same sum to 1.0 (double
    # rounding) — any lowering that narrows the widened arithmetic trips it.
    decomp[0, 0, 0] = 1.0
    resid[0, 0, 0] = np.float32(2.0 ** -24)
    orig[0, 0, 0] = 1.0
    eb = 1.0 + 2.0 ** -24
    return decomp, resid, orig, eb


def _probe_variant(variant_fn) -> bool:
    decomp, resid, orig, eb = _enhance_canaries()
    for mode in ("strict", "relaxed"):
        want_rec, want_mask = fused_enhance(decomp, resid, orig, eb,
                                            out_dtype=np.float32, mode=mode)
        got_rec, got_mask = variant_fn(decomp, resid, orig, eb,
                                       out_dtype=np.float32, mode=mode)
        if want_rec.tobytes() != np.asarray(got_rec).tobytes():
            return False
        if (want_mask is None) != (got_mask is None):
            return False
        if want_mask is not None and (want_mask.tobytes()
                                      != np.asarray(got_mask).tobytes()):
            return False
    return True


dispatch.register("fused_enhance", "eager", fused_enhance)
dispatch.register("fused_enhance", "jit", _fused_enhance_jit,
                  probe=functools.partial(_probe_variant, _fused_enhance_jit))
dispatch.register("fused_enhance", "pallas", _fused_enhance_pallas,
                  probe=functools.partial(_probe_variant,
                                          _fused_enhance_pallas),
                  backends=("tpu",))


def enhance_lowered(decomp: np.ndarray, resid_norm: np.ndarray,
                    orig: np.ndarray, eb: float, *, out_dtype=None,
                    mode: str = "strict", lowering: str = "auto"):
    """Dispatch-routed :func:`fused_enhance` (encode-side hot path)."""
    impl, _ = dispatch.resolve("fused_enhance", lowering)
    return impl(decomp, resid_norm, orig, eb, out_dtype=out_dtype, mode=mode)


def check_bound(orig: np.ndarray, rec: np.ndarray, eb: float, mode: str) -> dict:
    """Verification helper used by tests/benchmarks (paper 'error validation')."""
    err = np.abs(rec.astype(np.float64) - orig.astype(np.float64))
    finite = np.isfinite(np.asarray(orig, dtype=np.float64))
    maxerr = float(err[finite].max()) if finite.any() else 0.0
    limit = {"strict": eb, "relaxed": 2.0 * eb, "unregulated": np.inf}[mode]
    return {
        "max_abs_err": maxerr,
        "bound": limit,
        "ok": bool(maxerr <= limit),
        "olr": float((err[finite] > eb).mean()) if finite.any() else 0.0,
    }
