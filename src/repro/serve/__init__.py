"""`repro.serve` — archive serving and transcode tier.

The read-side counterpart of the streaming encoder: many consumers ask
one process for decoded fields, and the process answers fast without
blowing one shared memory ceiling.

* :class:`ArchiveServer` — concurrent decode requests (submit/future or
  blocking :meth:`~ArchiveServer.decode`), **coalesced** into stacked
  ``decompress_batched`` dispatches when same-signature requests land in
  the same batching window, fronted by a :class:`HotFieldCache` whose
  bytes are charged to the streaming engine's
  :class:`~repro.streaming.pipeline.ResidencyLedger`.
* :func:`transcode` — re-target a stored archive to new per-field error
  bounds, streaming entry-by-entry under the same ledger and writing a
  fresh container byte-identical to a whole-snapshot recompress.

Quickstart::

    from repro.serve import ArchiveServer, transcode

    with ArchiveServer("snapshot.nlz", max_bytes=1 << 30) as srv:
        temp = srv.decode("temperature")               # cold: decodes
        temp = srv.decode("temperature")               # hot: cache
        slab = srv.decode("velocity_x", roi=(slice(8, 16),))
        futs = [srv.submit(n) for n in ("f0", "f1", "f2")]
        fields = [f.result() for f in futs]            # coalesced batch

    transcode("snapshot.nlz", "cheap.nlz", bounds={"temperature": 1e-2},
              rel_eb=1e-3)

Instrumentation rides on ``repro.obs`` (``serve.*`` counters, a
``serve.coalesce_width`` gauge, per-request spans under a ``serve`` root
span) and fault handling on ``repro.faults`` (site ``"serve.request"``;
an injected fault fails that request's future, never the server).
"""
from __future__ import annotations

from .cache import HotFieldCache
from .coalesce import Coalescer, Future, Request
from .server import ArchiveServer
from .transcode import ArchiveSource, transcode

__all__ = ["ArchiveServer", "ArchiveSource", "Coalescer", "Future",
           "HotFieldCache", "Request", "transcode"]
