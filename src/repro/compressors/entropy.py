"""Host-side entropy stage.

TPU adaptation note (DESIGN.md §3): the device produces dense int32 quantization
codes; byte-granular entropy coding is pointer-chasing control flow that maps
poorly onto the MXU/VPU, so it runs on the host — the same split cuSZ uses
(GPU dual-quant + host/GPU Huffman).  We use zstd (level tunable) over the
narrowest integer representation of the code stream, which on near-zero
residual codes behaves like the Huffman+lossless stage of SZ3.

Also provides a first-order-entropy estimator used by the benchmarks to report
the idealized rate alongside the *real achieved* zstd bytes.
"""
from __future__ import annotations

import numpy as np

from . import codec

_ZSTD_LEVEL = 9


def _narrow(codes: np.ndarray) -> tuple[np.ndarray, str]:
    """Pick the narrowest int dtype that losslessly holds ``codes``."""
    if codes.size == 0:
        return codes.astype(np.int8), "int8"
    lo, hi = int(codes.min()), int(codes.max())
    for dt in ("int8", "int16", "int32", "int64"):
        info = np.iinfo(dt)
        if info.min <= lo and hi <= info.max:
            return codes.astype(dt), dt
    raise ValueError("codes exceed int64 range")


def encode_codes(codes: np.ndarray, level: int = _ZSTD_LEVEL) -> dict:
    """Entropy-encode an integer code stream.  Returns a serializable blob."""
    codes = np.ascontiguousarray(np.asarray(codes))
    narrow, dt = _narrow(codes.ravel())
    payload, cname = codec.compress(narrow.tobytes(), level)
    return {
        "dtype": dt,
        "shape": list(codes.shape),
        "payload": payload,
        "codec": cname,
        "nbytes": len(payload),
    }


def decode_codes(blob: dict) -> np.ndarray:
    raw = codec.decompress(blob["payload"], blob.get("codec", "zstd"))
    arr = np.frombuffer(raw, dtype=blob["dtype"]).reshape(blob["shape"])
    return arr.astype(np.int32)


def encode_floats(values: np.ndarray, level: int = _ZSTD_LEVEL) -> dict:
    """Lossless float blob (literals, DNN weights)."""
    values = np.ascontiguousarray(np.asarray(values))
    payload, cname = codec.compress(values.tobytes(), level)
    return {
        "dtype": str(values.dtype),
        "shape": list(values.shape),
        "payload": payload,
        "codec": cname,
        "nbytes": len(payload),
    }


def decode_floats(blob: dict) -> np.ndarray:
    raw = codec.decompress(blob["payload"], blob.get("codec", "zstd"))
    return np.frombuffer(raw, dtype=blob["dtype"]).reshape(blob["shape"]).copy()


def first_order_entropy_bits(codes: np.ndarray) -> float:
    """Idealized total bits for the code stream under an order-0 model."""
    codes = np.asarray(codes).ravel()
    if codes.size == 0:
        return 0.0
    _, counts = np.unique(codes, return_counts=True)
    p = counts / codes.size
    return float(-(p * np.log2(p)).sum() * codes.size)
