"""First-class ``Archive`` handle over both NeurLZ container formats.

One object wraps either a **whole-dict** archive (the in-memory format the
serial/batched engines produce and :func:`repro.core.archive.save` writes)
or a **streaming** ``NLZSTRM1`` container (the incremental format the
bounded-memory pipeline appends) and gives every consumer one surface:

* ``Archive.open(path)`` sniffs the format.  Opening a streaming container
  reads only the index footer — O(1) resident bytes no matter how large
  the snapshot is; no entry record is touched until asked for.
* ``arc.decode("temperature")`` is lazy random access: it reads exactly
  that field's entry plus its cross-field **aux closure** (each aux
  producer's entry, for its conventional reconstruction) and decodes only
  that — the decoder-side counterpart of the streaming encoder's
  refcounted residency.  Same-signature conventional archives in the
  closure decode through the registry's stacked ``decompress_batched``
  capability.
* ``arc.decode_all(engine=...)`` mirrors the old full decode
  (``engine="serial"`` streams one field at a time for streaming
  containers; ``engine="batched"`` fuses enhancer inference and
  conventional decode dispatches).
* ``arc.bitrate()`` / ``arc.save(path)`` round out the session surface.

The handle is also a read-only :class:`~collections.abc.Mapping` with the
whole-dict archive's keys (``"fields"``, ``"bitrate"``, ...), so legacy
code that indexes the dict keeps working unchanged — for a streaming
container those values materialize (and are cached) on first access,
keeping ``open`` itself cheap.
"""
from __future__ import annotations

import os
import shutil
from collections.abc import Mapping

import numpy as np

from .. import faults as faults_lib
from ..compressors import registry
from ..obs import telemetry as obs_lib
from . import archive as arc_io
from . import neurlz

_TOP_KEYS = ("kind", "fields", "slice_axis", "compressor", "timing",
             "bitrate")


def normalize_roi(roi, ndim: int) -> tuple:
    """Coerce a region-of-interest spec into a full tuple of slices.

    ``roi`` is a slice or a tuple of slices (shorter tuples extend with
    ``slice(None)`` on the trailing axes, like numpy basic indexing).
    Integers are rejected — a ROI decode always preserves the field's
    rank, so block-covering reads compose with further slicing.
    """
    if isinstance(roi, slice):
        roi = (roi,)
    if not isinstance(roi, tuple):
        raise TypeError(f"roi must be a slice or tuple of slices, "
                        f"got {type(roi).__name__}")
    if len(roi) > ndim:
        raise ValueError(f"roi has {len(roi)} axes for a {ndim}-d field")
    for s in roi:
        if not isinstance(s, slice):
            raise TypeError("roi entries must be slices (integers would "
                            f"drop an axis), got {type(s).__name__}")
    return roi + (slice(None),) * (ndim - len(roi))


class Archive(Mapping):
    """Handle over one compressed snapshot, whichever container holds it."""

    def __init__(self, arc: dict | None = None, *, reader=None,
                 path: str | None = None):
        if (arc is None) == (reader is None):
            raise ValueError("construct via Archive.open / Archive.from_dict")
        self._arc = arc                    # whole-dict backend
        self._reader = reader              # streaming backend (ArchiveReader)
        self._path = path
        self._entries: dict[str, dict] = {}     # streaming: cached entries
        self._bitrate: dict | None = None
        self.report: dict | None = None    # compression report, if any
        self.telemetry = obs_lib.NULL      # assign a Telemetry handle to
        #   trace decodes ("decode" spans, "archive.entry_reads" counter);
        #   repro.NeurLZ(telemetry=...) sets it on archives it opens
        self.faults = faults_lib.DEFAULT   # assign a FaultConfig to retry
        #   transient entry-read failures in decode (site "decode.entry");
        #   repro.NeurLZ(faults=...) sets it on archives it opens

    # -- constructors -------------------------------------------------------

    @classmethod
    def open(cls, source, *, repair: bool = False) -> "Archive":
        """Open either container format (path or binary file object).

        Streaming containers open lazily: only the index footer is read.
        Whole-dict files load the dict (that format is one msgpack blob —
        it has no random-access index to defer to).

        ``repair=True`` (streaming containers): skip the footer and rebuild
        the index by salvage-scanning the records — the way to open a
        footerless or truncated container from a crashed run.  Every
        checksum-intact entry is served; :attr:`salvaged` reports whether
        the container was unsealed.  Ignored for whole-dict files (one
        msgpack blob either loads or it doesn't).
        """
        if isinstance(source, (str, bytes, os.PathLike)):
            if arc_io.is_streaming_archive(source):
                return cls(reader=arc_io.ArchiveReader(source,
                                                       repair=repair),
                           path=os.fspath(source))
            return cls(arc=arc_io.load(source), path=os.fspath(source))
        source.seek(0)          # sniff from the start, wherever the caller
        head = source.read(8)   # left the position (e.g. just-written EOF)
        source.seek(0)
        if arc_io.is_streaming_archive(head):
            return cls(reader=arc_io.ArchiveReader(source, repair=repair))
        return cls(arc=arc_io.loads(source.read()))

    @classmethod
    def from_dict(cls, arc: dict) -> "Archive":
        """Wrap an in-memory whole-dict archive (no copy)."""
        if isinstance(arc, Archive):
            return arc
        return cls(arc=arc)

    # -- introspection ------------------------------------------------------

    @property
    def streaming(self) -> bool:
        """True when backed by an ``NLZSTRM1`` container (lazy entries)."""
        return self._reader is not None

    @property
    def path(self) -> str | None:
        return self._path

    @property
    def reader(self):
        """The underlying :class:`ArchiveReader` (streaming backend only);
        exposes the ``entry_reads`` accounting tests assert against."""
        return self._reader

    @property
    def meta(self) -> dict:
        if self.streaming:
            return self._reader.meta
        return {k: self._arc[k] for k in ("slice_axis", "compressor")}

    @property
    def salvaged(self) -> bool:
        """True when opened with ``repair=True`` against an unsealed
        container (the index was rebuilt by scanning, not read from a
        footer)."""
        return bool(self._reader is not None and self._reader.salvaged)

    @property
    def damage(self) -> list[dict]:
        """Damage report from a repair scan: one ``{"offset", "error"}``
        per unreadable region skipped (empty for clean/sealed opens)."""
        if self._reader is None:
            return []
        return list(self._reader.damage)

    def verify(self) -> dict:
        """Re-read every entry through the checksum path and report
        per-entry status: ``{"version", "sealed", "ok", "entries":
        {name: {"offset", "ok", "error"}}}``.  A clean container reports
        ``ok=True`` everywhere; a flipped bit pinpoints the failing entry
        and its record offset.  Whole-dict archives have no per-record
        checksums — they report trivially ok (the msgpack load already
        validated framing)."""
        if not self.streaming:
            return {"version": 0, "sealed": True, "ok": True,
                    "entries": {n: {"offset": None, "ok": True,
                                    "error": None}
                                for n in self.field_names}}
        source = self._path if self._path is not None else self._reader._f
        return arc_io.verify_container(source)

    @property
    def field_names(self) -> list[str]:
        """Entry names, snapshot order (block entries under their own
        ``name#bN`` names; see :attr:`block_manifest`)."""
        if self.streaming:
            order = self._reader.meta.get("field_order")
            if order is None:       # salvaged container without a prelude:
                return list(self._reader.entries)  # record order
            if self.salvaged:       # prelude lists the *planned* order —
                # a partial container only holds a prefix of it
                return [n for n in order if n in self._reader.entries]
            return list(order)
        return list(self._arc["fields"])

    @property
    def block_manifest(self) -> dict:
        """``BlockedSource`` reassembly manifest (empty when no field was
        split): original name -> ``{"axis", "blocks": [(entry, lo, hi)]}``."""
        if self.streaming:
            return dict(self._reader.meta.get("blocks") or {})
        return {}

    def entry(self, name: str) -> dict:
        """One field's raw archive entry (read from disk once, then cached —
        resident entries stay bounded by what you actually touch;
        accounting sweeps use :meth:`_entry_transient` so they don't pin
        the whole container)."""
        if not self.streaming:
            return self._arc["fields"][name]
        if name not in self._entries:
            self._entries[name] = self._read_entry(name)
            self.telemetry.counter("archive.entry_reads").add()
        return self._entries[name]

    def _read_entry(self, name: str) -> dict:
        """Entry read through the fault layer: probes the injection site
        ``"decode.entry"`` and retries transient read failures when a
        :class:`repro.faults.RetryPolicy` is configured."""
        return self.faults.run(lambda: self._reader.read_entry(name),
                               site="decode.entry", tel=self.telemetry)

    def _entry_transient(self, name: str) -> dict:
        """Read an entry WITHOUT inserting it into the cache (reuses a
        cached copy when present).  Used by whole-archive sweeps that only
        need per-entry metadata, so e.g. ``bitrate()`` over a 100-GB
        container does not leave every payload resident."""
        if not self.streaming or name in self._entries:
            return self.entry(name)
        self.telemetry.counter("archive.entry_reads").add()
        return self._read_entry(name)

    # -- decode -------------------------------------------------------------

    def decode(self, name: str, roi=None) -> np.ndarray:
        """Lazy random-access decode of one field.

        Touches only ``name``'s entry plus its cross-field aux closure (the
        entries whose conventional reconstructions feed its enhancer
        channels); for a streaming container nothing else is read from
        disk, and the records are read *transiently* — a field-by-field
        decode sweep stays O(one field + its aux set) resident instead of
        pinning every touched entry (use :meth:`entry` when you want a
        record cached).  ``name`` may also be a :attr:`block_manifest`
        original, in which case its blocks are decoded and concatenated.

        ``roi`` (a slice or tuple of slices, numpy basic-indexing style)
        restricts the result to a region of interest.  For a
        :attr:`block_manifest` original only the blocks covering the
        requested slab along the split axis are read and decoded — the
        others are never touched on disk (``entry_reads`` accounting
        reflects this).  A plain entry is self-contained, so its ROI is
        applied after a full decode.
        """
        man = self.block_manifest.get(name)
        if man is not None:
            return self._decode_blocked(man, roi)
        with self.telemetry.span("decode", field=name):
            e = self._entry_transient(name)
            conv = {name: e["conv"]}
            for a in e["aux"]:
                if a not in conv:
                    conv[a] = self._entry_transient(a)["conv"]
            recs = registry.decompress_many(conv)
            slice_axis = self["slice_axis"]
            out = neurlz.decode_field_entry(e, recs[name],
                                            [recs[a] for a in e["aux"]],
                                            slice_axis)
        if roi is None:
            return out
        return out[normalize_roi(roi, out.ndim)]

    def _decode_blocked(self, man: dict, roi) -> np.ndarray:
        """Decode a ``BlockedSource`` original, reading only the blocks
        that cover ``roi``'s slab along the split axis."""
        axis, blocks = man["axis"], man["blocks"]
        if roi is None:
            parts = [self.decode(bn) for bn, _, _ in blocks]
            return np.concatenate(parts, axis=axis)
        extent = blocks[-1][2]                 # blocks partition [0, extent)
        bshape = tuple(self._reader.meta["shapes"][blocks[0][0]])
        roi = normalize_roi(roi, len(bshape))
        idx = np.arange(*roi[axis].indices(extent))
        if idx.size == 0:
            e = self._entry_transient(blocks[0][0])
            dtype = np.dtype(e["conv"].get("dtype", "float32"))
            shape = tuple(
                len(range(*s.indices(extent if i == axis else bshape[i])))
                for i, s in enumerate(roi))
            return np.empty(shape, dtype=dtype)
        lo_need, hi_need = int(idx.min()), int(idx.max()) + 1
        # Other-axis slices apply inside each block; the split axis is
        # gathered afterwards so arbitrary steps (incl. negative) work.
        sub = tuple(s if i != axis else slice(None)
                    for i, s in enumerate(roi))
        parts, base = [], None
        for bn, lo, hi in blocks:
            if hi <= lo_need or lo >= hi_need:
                continue                       # block outside the slab:
            if base is None:                   #   never read from disk
                base = lo
            parts.append(self.decode(bn, roi=sub))
        cat = parts[0] if len(parts) == 1 else np.concatenate(parts,
                                                              axis=axis)
        return np.take(cat, idx - base, axis=axis)

    def decode_all(self, *, engine: str = "serial",
                   reassemble: bool = False) -> dict[str, np.ndarray]:
        """Decode every field.

        ``engine="serial"`` streams one field at a time for streaming
        containers (decode memory stays bounded by a field plus its live
        aux set); ``engine="batched"`` fuses enhancer inference per shape
        signature and amortizes conventional decode through
        ``decompress_batched``.  ``reassemble=True`` concatenates
        ``BlockedSource`` blocks back into their original fields.
        """
        if self.streaming and engine == "serial":
            from ..streaming import pipeline
            source = self._path if self._path is not None else self._reader._f
            return dict(pipeline.iter_decompress(source,
                                                 reassemble=reassemble))
        out = neurlz.decompress_impl(self, engine=engine)
        if reassemble and self.block_manifest:
            merged = dict(out)
            for orig, man in self.block_manifest.items():
                parts = [merged.pop(bn) for bn, _, _ in man["blocks"]]
                merged[orig] = np.concatenate(parts, axis=man["axis"])
            return merged
        return out

    # -- accounting / persistence ------------------------------------------

    def _num_points(self, name: str) -> int:
        if self.streaming:
            return int(np.prod(self._reader.meta["shapes"][name]))
        return int(np.prod(self._arc["fields"][name]["conv"]["shape"]))

    def bitrate(self, name: str | None = None) -> dict:
        """Paper bit-rate accounting; one field, or all (``name=None``).

        On a streaming container each entry is read transiently (sizes
        extracted, record dropped), so the sweep stays O(1) resident."""
        have_table = self._arc is not None and "bitrate" in self._arc
        if name is not None:
            if have_table:
                return self._arc["bitrate"][name]
            view = {"fields": {name: self._entry_transient(name)}}
            return neurlz.field_bitrate(view, name, self._num_points(name))
        if self._bitrate is None:
            if have_table:
                self._bitrate = self._arc["bitrate"]
            else:
                self._bitrate = {n: self.bitrate(n)
                                 for n in self.field_names}
        return self._bitrate

    def to_dict(self) -> dict:
        """Materialize the whole-dict archive format (reads every entry of
        a streaming container; byte-compatible with the in-memory engines'
        output).  Delegates to :func:`neurlz.assemble_streaming_archive` —
        the one implementation of the whole-dict assembly contract."""
        if not self.streaming:
            return self._arc
        if self._arc is None:
            self._arc = neurlz.assemble_streaming_archive(self._reader)
        return self._arc

    def save(self, path) -> int:
        """Write the archive to ``path`` (str or ``os.PathLike``) in its
        own container format; returns bytes written.  A streaming container
        copies through byte-for-byte (no entry is decoded)."""
        path = os.fspath(path)
        if not self.streaming:
            return arc_io.save(path, self._arc)
        if self._path is not None:
            shutil.copyfile(self._path, path)
            return os.path.getsize(path)
        f = self._reader._f
        pos = f.tell()
        f.seek(0)
        with open(path, "wb") as out:
            shutil.copyfileobj(f, out)
        f.seek(pos)
        return os.path.getsize(path)

    def close(self) -> None:
        if self._reader is not None:
            self._reader.close()

    def __del__(self):
        # Deterministic fd release for `arc = Archive.open(p)` rebinding
        # loops (legacy `core.load` callers never close); context-manager
        # use is still the recommended form.
        try:
            self.close()
        except Exception:
            pass

    def __enter__(self) -> "Archive":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read-only Mapping over the whole-dict archive keys -----------------

    def __getitem__(self, key):
        if not self.streaming:
            return self._arc[key]
        if key == "kind":
            return "neurlz"
        if key in ("slice_axis", "compressor"):
            return self._reader.meta[key]
        if key == "timing":
            return self._reader.meta.get("timing", {})
        if key == "fields":
            return self.to_dict()["fields"]
        if key == "bitrate":
            return self.bitrate()
        raise KeyError(key)

    def __iter__(self):
        return iter(_TOP_KEYS if self.streaming else self._arc)

    def __len__(self) -> int:
        return len(_TOP_KEYS) if self.streaming else len(self._arc)

    def __repr__(self) -> str:
        kind = "streaming" if self.streaming else "dict"
        where = f" path={self._path!r}" if self._path else ""
        return (f"<Archive {kind}{where} fields={len(self.field_names)}>")
