"""Deterministic synthetic token pipeline (LM training substrate).

Produces a seeded, *checkpointable* stream of token batches: the iterator
state is just ``(seed, step)``, so resuming a run after failure replays the
exact same data order (tested in ``tests/test_checkpoint.py``).  The
generator mimics natural-text statistics (Zipfian unigrams + short-range
repetition) so losses move like on real data.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStreamState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": int(self.seed), "step": int(self.step)}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class TokenStream:
    """Batch iterator: ``next_batch()`` -> int32 [batch, seq]."""

    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0,
                 zipf_a: float = 1.3):
        self.vocab = int(vocab_size)
        self.batch = int(batch)
        self.seq = int(seq)
        self.state = TokenStreamState(seed=seed, step=0)
        # Zipfian unigram distribution over the vocab.
        ranks = np.arange(1, self.vocab + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self._p = p / p.sum()

    def next_batch(self) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, self.state.step]))
        toks = rng.choice(self.vocab, size=(self.batch, self.seq),
                          p=self._p).astype(np.int32)
        # short-range repetition: copy spans backwards (learnable structure)
        n_spans = max(1, self.seq // 64)
        for b in range(self.batch):
            for _ in range(n_spans):
                ln = int(rng.integers(4, min(17, max(self.seq // 4, 5))))
                if self.seq < 2 * ln + 1:
                    continue
                src = int(rng.integers(0, self.seq - 2 * ln))
                dst = src + ln
                toks[b, dst:dst + ln] = toks[b, src:src + ln]
        self.state.step += 1
        return toks

    def checkpoint(self) -> dict:
        return self.state.to_dict()

    def restore(self, d: dict):
        self.state = TokenStreamState.from_dict(d)
