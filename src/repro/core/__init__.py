"""NeurLZ core — the paper's primary contribution as a composable JAX module.

Public API:
    NeurLZConfig, compress, decompress  — the enhancer pipeline
    skipping_dnn                        — the ~3k-param enhancer network
    online_trainer                      — compression-time learning loop
    regulation                          — 1×/2× error-bound modes
    metrics                             — PSNR/MAE/DSSIM/bitrate/OLR
"""
import jax

jax.config.update("jax_enable_x64", True)  # FP64 datasets (Miranda)

from . import archive, batched_engine, conv_stage, metrics, online_trainer, regulation, skipping_dnn  # noqa: E402,F401
from .neurlz import (NeurLZConfig, assemble_streaming_archive, compress,  # noqa: E402,F401
                     decompress, field_bitrate, load, save)
