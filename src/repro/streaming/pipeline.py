"""Bounded-memory streaming scheduler over the batched engine's group plan.

The in-memory engines materialize every field, keep every conventional
reconstruction resident for cross-field aux channels, and assemble the full
archive dict before a byte hits disk.  This scheduler runs the same
compression as a dataflow with a hard residency budget:

* **Plan from metadata** — groups come from
  :func:`repro.core.batched_engine.plan_groups_from_meta` using only field
  shapes, then are walked in a cross-field dependency-aware order
  (:func:`order_groups`): greedily pick the group that frees the most
  resident reconstruction bytes and materializes the fewest new ones.
* **Refcounted residency** — each conventional reconstruction carries a
  refcount (its own finalize + one per cross-field consumer) and is
  evicted the moment the last consumer finishes.  Originals are evicted
  right after their group's outlier capture; an aux producer whose own
  group runs later is conv-compressed early from a transient load.
* **Hard budget** — every resident array (originals, reconstructions,
  training tensors) is charged to a :class:`ResidencyLedger`; admission of
  the next group blocks behind retirement of in-flight groups, and a group
  whose working set cannot fit raises with the live set in the message.
  (Packed entries in the bounded writer queue ride outside the ledger;
  they are codec-compressed payloads plus a 1-byte-per-point outlier mask,
  small next to the raw arrays the ledger tracks.)
* **Overlap** — the next group's source loads run on a reader thread while
  the current group trains on device, and entry packing + archival run on
  the :class:`repro.streaming.writer.AsyncArchiveWriter` thread behind a
  bounded queue.

Training and packing go through the exact serial-engine helpers (the
batched engine's group dispatch, whose strategies are all byte-identical
to serial for the groups they accept), so streamed archive entries are
bit-identical to ``engine="serial"`` output.
"""
from __future__ import annotations

import dataclasses
import io
import os
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from typing import Mapping

import numpy as np

from .. import faults as faults_lib
from ..compressors import registry
from ..core import archive as arc_io
from ..core import batched_engine, neurlz
from ..core import bounds as bounds_lib
from ..core import conv_stage as conv_stage_lib
from ..obs import telemetry as obs_lib
from . import source as source_lib
from .writer import AsyncArchiveWriter, EntryTask


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Streaming-only knobs (the budget itself usually comes from
    ``NeurLZConfig.max_resident_bytes``; set it here to override)."""
    max_resident_bytes: int | None = None
    writer_queue: int = 4       # pending entries before put() back-pressures
    depth: int = 2              # dispatched-but-unretired groups in flight
    prefetch: bool = True       # reader-thread lookahead of the next group
    container_version: int = 2  # 2 = durable NLZSTRM2 (checksums + salvage);
    #   1 = legacy NLZSTRM1 byte stream
    durability: str = "none"    # none | flush | fsync — how eagerly sealed
    #   entries reach disk (fsync: an entry survives OS crash, not just
    #   process death)
    checksum: str = "crc32"     # per-record checksum algo (v2): crc32 |
    #   crc32c (needs the optional crc32c wheel)


class ResidencyLedger:
    """Byte accounting for every resident array, with a hard ceiling.

    ``max_bytes <= 0`` disables the ceiling but still tracks the peak (the
    number reported by benchmarks and asserted by tests).
    """

    def __init__(self, max_bytes: int = 0, telemetry=None):
        self.max_bytes = int(max_bytes)
        self.current = 0
        self.peak = 0
        self._items: dict[str, int] = {}
        self._lock = threading.Lock()
        self.tel = telemetry if telemetry is not None else obs_lib.NULL
        self.tel.gauge("stream.resident_bytes_max").set(self.max_bytes)

    def __contains__(self, key: str) -> bool:
        return key in self._items

    def fits(self, nbytes: int) -> bool:
        return self.max_bytes <= 0 or self.current + nbytes <= self.max_bytes

    def add(self, key: str, nbytes: int) -> None:
        with self._lock:
            self.current += int(nbytes) - self._items.get(key, 0)
            self._items[key] = int(nbytes)
            self.peak = max(self.peak, self.current)
        self.tel.gauge("stream.resident_bytes").set(self.current)

    def drop(self, key: str) -> None:
        with self._lock:
            existed = key in self._items
            self.current -= self._items.pop(key, 0)
        if existed:
            self.tel.counter("stream.evictions").add()
            self.tel.gauge("stream.resident_bytes").set(self.current)


def order_groups(groups, aux_map, metas):
    """Cross-field dependency-aware walk order (greedy, deterministic).

    Score of a candidate group = reconstruction bytes its retirement frees
    minus bytes it must newly materialize; ties fall back to plan order.
    Ordering never changes outputs (entries depend only on their own field,
    its aux reconstructions and the seed), only peak residency.
    """
    names_all = [n for g in groups for n in g.names]
    refs = {n: 1 for n in names_all}
    for n in names_all:
        for a in aux_map.get(n, ()):
            refs[a] = refs.get(a, 0) + 1
    resident: set[str] = set()
    remaining = list(groups)
    order = []

    def score(g):
        need = set()
        drops: dict[str, int] = {}
        for n in g.names:
            need.add(n)
            need.update(aux_map.get(n, ()))
            for m in (n, *aux_map.get(n, ())):
                drops[m] = drops.get(m, 0) + 1
        freed = sum(metas[m].nbytes for m, d in drops.items()
                    if refs[m] - d <= 0)
        new = sum(metas[m].nbytes for m in need if m not in resident)
        return freed - new

    while remaining:
        best = max(range(len(remaining)),
                   key=lambda i: (score(remaining[i]), -i))
        g = remaining.pop(best)
        order.append(g)
        for n in g.names:
            for m in (n, *aux_map.get(n, ())):
                resident.add(m)
                refs[m] -= 1
                if refs[m] <= 0:
                    resident.discard(m)
    return order


class _NullCtx:
    """No-op stand-in for the straggler watchdog's step context."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class _SnapshotView(dict):
    """Group arrays plus name-membership over the *whole* snapshot, so the
    shared engine helpers can validate cross-field aux names against fields
    that are not resident."""

    def __init__(self, arrays, all_names):
        super().__init__(arrays)
        self._all = frozenset(all_names)

    def __contains__(self, key) -> bool:  # noqa: D105
        return key in self._all


def _dataset_nbytes(meta: source_lib.FieldMeta, c_in: int,
                    slice_axis: int) -> int:
    """float32 training-tensor bytes: inputs [N,H,W,c_in] + targets 1ch."""
    sliced = batched_engine.sliced_shape(meta.shape, slice_axis)
    return int(np.prod(sliced)) * 4 * (c_in + 1)


def _config_signature(config, rel_eb, abs_eb) -> dict:
    """The compatibility fingerprint a resumed run must match: everything
    that changes entry bytes.  Recorded in the v2 prelude, compared before
    salvaged entries are trusted."""
    return {
        "compressor": config.compressor,
        "mode": config.mode,
        "seed": config.seed,
        "epochs": config.epochs,
        "batch": config.batch,
        "lr": config.lr,
        "slice_axis": config.slice_axis,
        "skip": config.skip,
        "learn_residual": config.learn_residual,
        "weight_dtype": config.weight_dtype,
        "widths": list(config.widths),
        "rel_eb": rel_eb,
        "abs_eb": abs_eb,
    }


def _salvage_for_resume(sink, names, sig) -> dict[str, dict]:
    """Pull every intact entry out of a partial container at ``sink`` before
    the fresh :class:`ArchiveAppender` truncates it.

    Returns ``{name: entry}`` for the completed fields (held in memory —
    packed entries are codec-compressed, small next to raw fields).  An
    absent/foreign file resumes as a fresh run; a container written under a
    different config signature or field set is a hard error — silently
    mixing entries from two runs would break the per-entry byte-identity
    contract.
    """
    if not isinstance(sink, (str, bytes, os.PathLike)):
        return {}
    if not (os.path.exists(sink) and os.path.getsize(sink) > 0
            and arc_io.is_streaming_archive(sink)):
        return {}
    out: dict[str, dict] = {}
    with arc_io.ArchiveReader(sink, repair=True) as r:
        pre = r.prelude or {}
        old_sig = pre.get("config_sig")
        if old_sig is not None and sig is not None and old_sig != sig:
            diff = sorted(k for k in sig
                          if old_sig.get(k) != sig.get(k))
            raise ValueError(
                f"resume: partial container at {os.fspath(sink)!r} was "
                f"written under a different configuration (differs in "
                f"{diff}); delete it or rerun with the original settings")
        stale = sorted(set(r.entries) - set(names))
        if stale:
            raise ValueError(
                f"resume: partial container holds fields {stale} that are "
                "not in this snapshot; refusing to mix runs")
        for name in r.entries:
            try:
                entry = r.read_entry(name)
            except arc_io.CorruptArchiveError:
                continue        # torn/corrupt record: recompress that field
            if entry.get("degraded"):
                continue        # give a degraded field another chance
            out[name] = entry
    return out


def compress(source, sink, rel_eb: float | None = None, *,
             abs_eb: float | None = None, config=None,
             collect_stats: bool = True,
             stream: StreamConfig | None = None, bounds=None,
             resume: bool = False, ledger: ResidencyLedger | None = None
             ) -> dict:
    """Stream-compress a snapshot into an incremental archive container.

    ``source`` is anything :func:`repro.streaming.source.as_source`
    accepts (dict of arrays, ``.npy`` directory, or a
    :class:`ChunkedFieldSource`); ``sink`` is a path or binary file
    object.  ``bounds`` carries per-field
    :class:`repro.core.bounds.ErrorBound` specs (groups are planned
    mode-homogeneous, and the conventional stage batches per bound spec).
    Returns a report dict (timing, peak residency, writer stats).
    Entries are bit-identical to ``engine="serial"`` archives.

    ``resume=True``: when ``sink`` is a path holding a partial container
    from a killed run, every intact entry is salvaged (byte-identical
    re-append), the completed fields are skipped, and only the rest is
    compressed — a crashed streaming run loses at most its in-flight
    group.  The salvaged container must carry a matching config prelude;
    a mismatch is a hard error, never silent mixing.

    ``ledger``: hand in an existing :class:`ResidencyLedger` to share one
    memory ceiling with other subsystems (the serving tier's hot-field
    cache charges the same ledger, so a transcode running beside a cache
    stays under *one* process budget).  When given, the ledger's own
    ``max_bytes`` is the ceiling and ``max_resident_bytes`` from the
    config/stream knobs is ignored; the reported
    ``peak_resident_bytes`` then covers everything charged to the shared
    ledger, not just this run.  Ledger sharing never changes archive
    bytes — only admission order and peaks.
    """
    config = config or neurlz.NeurLZConfig(engine="streaming")
    stream = stream or StreamConfig()
    tel = obs_lib.of(config)
    fc = faults_lib.of(config)
    budget = (stream.max_resident_bytes
              if stream.max_resident_bytes is not None
              else config.max_resident_bytes)
    if ledger is not None:
        budget = ledger.max_bytes
    t0 = time.time()
    with tel.span("compress", root=True, engine="streaming") as root_sp:
        with tel.span("plan"):
            src = source_lib.as_source(source)
            names = src.names()
            metas = {n: src.meta(n) for n in names}
            resolved = None
            if bounds is not None:
                resolved = bounds_lib.resolve_bounds(
                    names, bounds, rel_eb, abs_eb, default_mode=config.mode)
            modes = ({n: b.mode for n, b in resolved.items()}
                     if resolved is not None else None)
            aux_map = {n: list(config.cross_field.get(n, ()))
                       for n in names}
            for n, aux in aux_map.items():
                missing = [a for a in aux if a not in metas]
                if missing:
                    raise KeyError(
                        f"cross-field aux {missing} not in input fields")
            c_ins = {n: 1 + len(aux_map[n]) for n in names}
            sig = _config_signature(config, rel_eb, abs_eb)
            # Salvage BEFORE the appender below truncates the sink; the
            # salvaged fields drop out of the group plan entirely (their
            # reconstructions are still conv-compressed on demand when an
            # unfinished field needs them as aux — dependency order holds).
            salvaged: dict[str, dict] = {}
            if resume:
                salvaged = _salvage_for_resume(sink, names, sig)
            remaining = [n for n in names if n not in salvaged]
            groups = batched_engine.plan_groups_from_meta(
                {n: metas[n].shape for n in remaining},
                {n: c_ins[n] for n in remaining}, config,
                modes=({n: modes[n] for n in remaining}
                       if modes is not None else None))
            order = order_groups(groups, aux_map, metas)
        root_sp.set(fields=len(names), groups=len(order),
                    resumed=len(salvaged))

        rec_refs = {n: 1 for n in remaining}
        for n in remaining:
            for a in aux_map[n]:
                rec_refs[a] = rec_refs.get(a, 0) + 1

        # The prelude makes a crashed container self-describing: the
        # salvage scanner and a later resume know the field set and config
        # without ever reaching the (never-written) footer.
        prelude = {
            "field_order": names,
            "shapes": {n: list(metas[n].shape) for n in names},
            "slice_axis": config.slice_axis,
            "compressor": config.compressor,
            "aux": aux_map,
            "config_sig": sig,
        }
        tcfg = config.train_config()
        if ledger is None:
            ledger = ResidencyLedger(budget, telemetry=tel)
        writer = AsyncArchiveWriter(sink, config,
                                    collect_stats=collect_stats,
                                    queue_size=stream.writer_queue,
                                    telemetry=tel, faults=fc,
                                    version=stream.container_version,
                                    durability=stream.durability,
                                    checksum=stream.checksum,
                                    prelude=prelude)
        # Re-append the salvaged entries first, in snapshot field order —
        # msgpack round-trips deterministically, so each re-appended entry
        # is byte-identical to the killed run's (and to a serial run's).
        for n in names:
            if n in salvaged:
                writer.put_entry(n, salvaged[n])
        watchdog = None
        if fc.straggler_deadline_s is not None:
            watchdog = faults_lib.StepWatchdog(
                fc.straggler_deadline_s,
                on_straggler=lambda i: tel.counter("faults.stragglers").add())
        reader = ThreadPoolExecutor(max_workers=1,
                                    thread_name_prefix="neurlz-reader")
        xs: dict[str, np.ndarray] = {}
        conv_arcs: dict[str, dict] = {}
        recs: dict[str, np.ndarray] = {}
        ebs: dict[str, float] = {}
        in_flight: deque = deque()
        # Shared conventional stage: a training group's freshly loaded
        # fields compress as one batched plan under the existing residency
        # ledger (the loaded originals and their reconstructions are
        # already charged).
        stage = conv_stage_lib.ConvStage(config.compressor, rel_eb, abs_eb,
                                         batch=config.conv_batch,
                                         bounds=resolved, telemetry=tel,
                                         lowering=config.lowering)
        want_traces = tel.enabled and tel.config.learning_traces

        def group_cost(group) -> dict[str, int]:
            cost = {}
            for n in group.names:
                xb = metas[n].nbytes
                cost[f"x:{n}"] = xb
                if f"rec:{n}" not in ledger:
                    cost[f"rec:{n}"] = xb
                cost[f"ds:{n}"] = _dataset_nbytes(metas[n], group.c_in,
                                                  config.slice_axis)
            return cost

        def conv_many(arrays: Mapping[str, np.ndarray]) -> None:
            if not arrays:
                return
            # The fused batched path materializes group-sized working
            # copies (float64 casts, the stacked array, code/mask planes);
            # charge an envelope for them so the fused dispatch respects
            # the budget.  If it cannot fit even after retiring in-flight
            # groups, fall back to per-field compression — one field's
            # transients at a time, the historical (uncharged) envelope.
            use_batch = len(arrays) > 1 and config.conv_batch
            if use_batch:
                tmp = 3 * sum(np.asarray(a).size * 8
                              for a in arrays.values())
                while not ledger.fits(tmp) and in_flight:
                    retire(in_flight.popleft())
                if ledger.fits(tmp):
                    ledger.add("convtmp", tmp)
                else:
                    use_batch = False
            try:
                out = stage.run(arrays, batch=use_batch)
            finally:
                ledger.drop("convtmp")
            for name, (arc, rec) in out.items():
                conv_arcs[name], recs[name], ebs[name] = \
                    arc, rec, arc["abs_eb"]

        def unref_rec(name: str) -> None:
            rec_refs[name] -= 1
            if rec_refs[name] <= 0:
                recs.pop(name, None)
                ledger.drop(f"rec:{name}")

        def retire(state) -> None:
            """Sync the oldest group, hand entries to the writer, evict.
            A per-field enhancer failure (injected, non-finite loss, OOM in
            enhancement) degrades that field to a conv-only entry instead
            of aborting the snapshot."""
            gcfg = batched_engine.group_config(config, state.group)
            with tel.span("retire", group=",".join(state.group.names)):
                for f, name, hist, resid in \
                        batched_engine.group_results(state):
                    x = np.asarray(xs[name])
                    reason, mask = None, None
                    try:
                        fc.check(f"train.{name}")
                        if fc.degrade and not neurlz.history_is_finite(hist):
                            reason = faults_lib.degrade_reason()
                        else:
                            _, mask = neurlz.enhance_and_mask(
                                x, recs[name], resid, ebs[name],
                                state.stats[f], gcfg)
                    except Exception as exc:
                        if not (fc.degrade and faults_lib.is_degradable(exc)):
                            raise
                        reason = faults_lib.degrade_reason(exc)
                    if reason is not None:
                        writer.put(EntryTask(
                            name=name, conv_arc=conv_arcs.pop(name),
                            params=None, stats=[], aux=[], eb=ebs[name],
                            net_cfg=None, history=[], mask=None,
                            mode=state.group.mode, degraded=reason))
                    else:
                        trace = ((neurlz.field_vrange(x), int(x.size))
                                 if want_traces else None)
                        writer.put(EntryTask(
                            name=name, conv_arc=conv_arcs.pop(name),
                            params=state.params[f], stats=state.stats[f],
                            aux=aux_map[name], eb=ebs[name],
                            net_cfg=state.net_cfg, history=hist, mask=mask,
                            mode=state.group.mode, trace=trace))
                    xs.pop(name, None)
                    ledger.drop(f"x:{name}")
                    ledger.drop(f"ds:{name}")
                    unref_rec(name)
                    for a in aux_map[name]:
                        unref_rec(a)

        def admit(cost: dict[str, int], what: str) -> None:
            need = sum(cost.values())
            while not ledger.fits(need) and in_flight:
                retire(in_flight.popleft())
            if not ledger.fits(need):
                live = sorted(k for k in ledger._items)
                raise MemoryError(
                    f"max_resident_bytes={budget} cannot admit {what} "
                    f"(needs {need} more bytes over {ledger.current} "
                    f"resident: {live}); raise the budget, lower "
                    f"group_size, or wrap the source in BlockedSource")
            for k, v in cost.items():
                ledger.add(k, v)

        def load_field(name: str) -> np.ndarray:
            """Source load under the fault layer: the ``"reader.load"``
            site is probed per attempt and transient I/O errors retry
            under the configured policy."""
            return fc.run(lambda: src.load(name), site="reader.load",
                          tel=tel)

        def ensure_aux_rec(name: str) -> None:
            """Conv-compress an aux producer early (transient load)."""
            if name in recs:
                return
            cost = {f"rec:{name}": metas[name].nbytes,
                    f"tmpx:{name}": metas[name].nbytes}
            admit(cost, f"aux reconstruction of {name!r}")
            conv_many({name: load_field(name)})
            ledger.drop(f"tmpx:{name}")

        def prefetch_load(group):
            # Runs on the reader thread: its "read" span has no enclosing
            # span there, so it parents to the run's root span.
            with tel.span("read", group=",".join(group.names)):
                return {n: load_field(n) for n in group.names}

        prefetched = None           # (group, future, cost) for order[i+1]
        t_train0 = time.time()
        conv_before = stage.stats.conv_s
        try:
            for gi, group in enumerate(order):
                straggle = (watchdog.step(gi) if watchdog is not None
                            else _NULL_CTX)
                with straggle:
                    if prefetched is not None and prefetched[0] is group:
                        arrays = prefetched[1].result()
                    else:
                        admit(group_cost(group), f"group {group.names}")
                        with tel.span("load", group=",".join(group.names)):
                            arrays = {n: load_field(n) for n in group.names}
                    prefetched = None
                    xs.update(arrays)
                    # Conv-compress the group's own fields first (fused,
                    # from the already-loaded arrays) so an in-group aux
                    # producer never takes the transient-reload path below.
                    conv_many({n: xs[n] for n in group.names
                               if n not in recs})
                    for name in group.names:
                        for a in aux_map[name]:
                            ensure_aux_rec(a)
                    with tel.span("train", group=",".join(group.names)):
                        state = batched_engine._prepare_group(
                            group,
                            _SnapshotView({n: xs[n] for n in group.names},
                                          names),
                            recs, ebs, config, tcfg)
                        batched_engine._dispatch_group(state, config, tcfg)
                in_flight.append(state)
                # Retire down to depth BEFORE prefetching: steady-state
                # residency is then depth working sets, so a budget of ~2
                # group working sets still gets reader-thread lookahead.
                while len(in_flight) > max(1, stream.depth) - 1:
                    retire(in_flight.popleft())
                # Reader-thread lookahead: load the next group's originals
                # while this group trains on device (skipped, not blocked,
                # when the budget cannot take both working sets at once).
                if gi + 1 < len(order) and stream.prefetch:
                    nxt = order[gi + 1]
                    cost = group_cost(nxt)
                    if ledger.fits(sum(cost.values())):
                        for k, v in cost.items():
                            ledger.add(k, v)
                        fut = reader.submit(prefetch_load, nxt)
                        prefetched = (nxt, fut, cost)
            while in_flight:
                retire(in_flight.popleft())
            train_time = (time.time() - t_train0) \
                - (stage.stats.conv_s - conv_before)

            # Drain the writer queue before building timing: degradation
            # decisions are made at pack time on the writer thread, and the
            # footer's timing must already list them.
            writer.drain()
            timing = obs_lib.build_timing(
                tel, total_s=time.time() - t0, conv_s=stage.stats.conv_s,
                train_s=train_time, conv_stage=stage.stats.as_dict(),
                peak_resident_bytes=ledger.peak,
                max_resident_bytes=budget,
                degraded_fields=list(writer.degraded),
                resumed_fields=sorted(salvaged))
            if watchdog is not None:
                timing["straggler_overruns"] = len(watchdog.overruns)
            meta = {
                "field_order": names,
                "shapes": {n: list(metas[n].shape) for n in names},
                "slice_axis": config.slice_axis,
                "compressor": config.compressor,
                "aux": aux_map,
                "blocks": dict(getattr(src, "manifest", {}) or {}),
                "timing": timing,
            }
            with tel.span("flush"):
                stats = writer.close(meta)
            timing["total_s"] = time.time() - t0
            if tel.enabled:
                # Refresh: the writer thread's spans land during close().
                timing["spans"] = tel.span_summary()
            return {**timing, **stats, "field_order": names,
                    "groups": len(order)}
        except BaseException:
            writer.abort()
            raise
        finally:
            if prefetched is not None:
                prefetched[1].cancel()
            reader.shutdown(wait=True)
            # Release every charge this run still holds — on the success
            # path they are already gone, but an aborted run sharing an
            # external ledger must not leave phantom bytes pinned against
            # another subsystem's ceiling (e.g. the serving cache).
            for k in list(ledger._items):
                if k.startswith(("x:", "rec:", "ds:", "tmpx:", "convtmp")):
                    ledger.drop(k)


class PipelineScheduler:
    """Configured handle over the streaming scheduler.

    Holds the ``NeurLZConfig`` + :class:`StreamConfig` pair so repeated
    snapshots (e.g. successive simulation timesteps) run with one budget:

        sched = PipelineScheduler(cfg, StreamConfig())
        for step, src in snapshots:
            report = sched.run(src, f"snap_{step}.nlzs", rel_eb=1e-3)
    """

    def __init__(self, config=None, stream: StreamConfig | None = None):
        self.config = config or neurlz.NeurLZConfig(engine="streaming")
        self.stream = stream or StreamConfig()

    def run(self, source, sink, rel_eb: float | None = None, *,
            abs_eb: float | None = None, collect_stats: bool = True,
            bounds=None, resume: bool = False,
            ledger: ResidencyLedger | None = None) -> dict:
        return compress(source, sink, rel_eb, abs_eb=abs_eb,
                        config=self.config, collect_stats=collect_stats,
                        stream=self.stream, bounds=bounds, resume=resume,
                        ledger=ledger)


def compress_dict(fields, rel_eb: float | None = None, *,
                  abs_eb: float | None = None, config=None,
                  collect_stats: bool = True, bounds=None) -> dict:
    """``engine="streaming"`` entry point for :func:`repro.core.compress`:
    run the full pipeline (scheduler, budget, writer thread) against an
    in-memory sink, then reassemble the whole-dict archive contract."""
    buf = io.BytesIO()
    report = compress(fields, buf, rel_eb, abs_eb=abs_eb, config=config,
                      collect_stats=collect_stats, bounds=bounds)
    buf.seek(0)
    with arc_io.ArchiveReader(buf) as r:
        arc = neurlz.assemble_streaming_archive(r)
    arc["timing"] = {**arc["timing"],
                     **{k: report[k] for k in
                        ("writer_busy_s", "writer_put_wait_s",
                         "writer_close_wait_s", "bytes_written", "entries",
                         "spans")
                        if k in report}}
    return arc


# ---------------------------------------------------------------------------
# Streaming decode: one field at a time from the incremental container
# ---------------------------------------------------------------------------

def iter_decompress(source, *, reassemble: bool = True):
    """Yield ``(name, array)`` one field at a time from a streaming archive.

    Only the reconstructions still needed as cross-field aux stay resident
    (same refcounting as the encoder), so decode memory is bounded by the
    largest field plus its live aux set.  Conventional decodes that become
    due together (a field plus its not-yet-resident aux producers) run
    through the registry's batched ``decompress_batched`` capability when
    their archives share a decode signature — bit-identical to per-field
    decode, fewer dispatches.  With ``reassemble=True`` (the default),
    blocks written through :class:`BlockedSource` are concatenated back
    into their original fields before being yielded.
    """
    with arc_io.ArchiveReader(source) as r:
        meta = r.meta
        order = list(meta["field_order"])
        aux_map = meta.get("aux", {})
        slice_axis = meta["slice_axis"]
        blocks = meta.get("blocks") or {}
        block_owner = {bname: orig for orig, man in blocks.items()
                       for bname, _, _ in man["blocks"]}

        refs = {n: 1 for n in order}
        for n in order:
            for a in aux_map.get(n, ()):
                refs[a] += 1
        recs: dict[str, np.ndarray] = {}

        def unref(name: str) -> None:
            refs[name] -= 1
            if refs[name] <= 0:
                recs.pop(name, None)

        pending: dict[str, dict[str, np.ndarray]] = {}
        for name in order:
            e = r.read_entry(name)
            # One batched conventional decode for everything this step
            # newly needs: the field itself plus any aux producers whose
            # reconstructions are not resident yet.
            due = {}
            if name not in recs:
                due[name] = e["conv"]
            for a in e["aux"]:
                if a not in recs and a not in due:
                    due[a] = r.read_entry(a)["conv"]
            recs.update(registry.decompress_many(due))
            rec = recs[name]
            aux = [recs[a] for a in e["aux"]]
            out = neurlz.decode_field_entry(e, rec, aux, slice_axis)
            unref(name)
            for a in e["aux"]:
                unref(a)
            if reassemble and name in block_owner:
                orig = block_owner[name]
                man = blocks[orig]
                pending.setdefault(orig, {})[name] = out
                if len(pending[orig]) == len(man["blocks"]):
                    parts = [pending[orig][bn] for bn, _, _ in man["blocks"]]
                    yield orig, np.concatenate(parts, axis=man["axis"])
                    del pending[orig]
            else:
                yield name, out


def decompress(source, *, reassemble: bool = True) -> dict[str, np.ndarray]:
    """Materialize :func:`iter_decompress` into a dict (field order of the
    snapshot, block-reassembled by default)."""
    return dict(iter_decompress(source, reassemble=reassemble))
