"""Checkpointing: atomicity, retention, lossy weights, resume determinism,
elastic re-sharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpoint import CheckpointManager
from repro.checkpoint.fault_tolerance import (FailureInjector,
                                              SimulatedFailure, StepWatchdog)
from repro.data.tokens import TokenStream
from repro.models import model as M


def _tiny_state(seed=0):
    cfg = configs.get_reduced("qwen3-4b")
    model = M.build_model(cfg, model_axis=1)
    params, opt = M.init_train_state(model, seed=seed)
    return cfg, model, params, opt


def test_save_restore_exact(tmp_path):
    cfg, model, params, opt = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(5, params, opt, extra={"stream": {"seed": 0, "step": 5}})
    p2, o2, meta = mgr.restore(5, params, opt)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert meta["extra"]["stream"]["step"] == 5


def test_retention_keeps_newest(tmp_path):
    cfg, model, params, opt = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, params)
    assert mgr.manifest()["steps"] == [3, 4]
    assert not os.path.exists(str(tmp_path / "step_1"))
    assert mgr.latest_step() == 4


def test_lossy_weights_bounded(tmp_path):
    cfg, model, params, opt = _tiny_state()
    eb = 1e-4
    mgr = CheckpointManager(str(tmp_path), keep=1, lossy_weights_eb=eb)
    mgr.save(1, params)
    p2, _, _ = mgr.restore(1, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
        if a.ndim >= 2:
            rng = a.max() - a.min()
            if rng > 0:
                assert np.abs(a - b).max() <= eb * rng * (1 + 1e-6)
        else:
            assert np.array_equal(a, b)  # 1-D stays lossless


def test_resume_determinism(tmp_path):
    """Training with a mid-run failure + restart reaches the same state as
    an uninterrupted run (exactness of checkpoint + data stream replay)."""
    cfg, model, params0, opt0 = _tiny_state()
    step_fn = jax.jit(M.make_train_step(model, lr=1e-3))

    def run(n_steps, mgr=None, fail_at=None, resume=False):
        params, opt = jax.tree.map(lambda x: x, (params0, opt0))
        stream = TokenStream(cfg.vocab_size, 2, 32, seed=0)
        start = 0
        if resume and mgr.latest_step() is not None:
            params, opt, meta = mgr.restore(mgr.latest_step(), params, opt)
            stream.restore(meta["extra"]["stream"])
            start = mgr.latest_step()
        inj = FailureInjector(fail_at)
        for step in range(start, n_steps):
            batch = {"tokens": jnp.asarray(stream.next_batch())}
            params, opt, m = step_fn(params, opt, batch,
                                     jnp.asarray(step, jnp.int32))
            inj.maybe_fail(step)
            if mgr is not None:
                mgr.save(step + 1, params, opt,
                         extra={"stream": stream.checkpoint()})
        return params, float(m["loss"])

    # uninterrupted reference
    ref_params, ref_loss = run(6)
    # interrupted run with restart
    mgr = CheckpointManager(str(tmp_path), keep=2)
    with pytest.raises(SimulatedFailure):
        run(6, mgr=mgr, fail_at=3)
    got_params, got_loss = run(6, mgr=mgr, resume=True)
    assert abs(ref_loss - got_loss) < 1e-6
    for a, b in zip(jax.tree.leaves(ref_params), jax.tree.leaves(got_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_elastic_reshard_roundtrip(tmp_path):
    """Save from the default placement, restore through the elastic path."""
    from repro.distributed.elastic import rescale
    from repro.launch.mesh import make_host_mesh

    cfg, model, params, opt = _tiny_state()
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, params, opt, extra={"stream": {"seed": 0, "step": 0}})
    mesh = make_host_mesh()
    p2, o2, meta = rescale(mgr, 1, params, opt, mesh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_watchdog_records_overrun():
    import time

    fired = []
    wd = StepWatchdog(deadline_s=0.05, on_straggler=fired.append)
    with wd.step(0):
        time.sleep(0.12)
    with wd.step(1):
        pass
    assert fired == [0]
    assert wd.stats()["overruns"] == 1
    assert wd.stats()["steps"] == 2
