"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — but a
scan-over-layers training step does ~all of its work inside nested while
loops (layer segments × microbatches × attention chunks), so its FLOP/byte
numbers undercount by the product of trip counts.  This module re-derives
them from ``compiled.as_text()``:

  * every while op carries ``backend_config={"known_trip_count":{"n":...}}``
    (static scan bounds) — nested loop costs multiply out;
  * dot/convolution FLOPs from operand shapes + contracting dims;
  * bytes ≈ Σ (operand + result bytes) per instruction at fusion boundaries
    (the same HBM-traffic proxy XLA's own analysis uses);
  * collectives are tallied per enclosing loop with ring-algorithm wire
    factors and replica-group sizes (see ``roofline.wire_factor``).

The result is the per-device cost of one step of the *SPMD-partitioned*
module — exactly what the roofline terms need.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "and",
    "or", "xor", "not", "negate", "abs", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "compare", "select", "clamp", "sign", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "remainder", "atan2",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "logistic", "rsqrt", "sqrt",
                   "power", "sine", "cosine", "expm1", "log1p", "erf", "cbrt"}
_MOVE = {"copy", "transpose", "broadcast", "iota", "reverse", "pad",
         "concatenate", "slice", "dynamic-slice", "dynamic-update-slice",
         "gather", "scatter", "convert", "reduce", "reduce-window",
         "select-and-scatter", "sort", "rng", "rng-bit-generator", "map",
         "reshape", "cholesky", "triangular-solve", "fft", "clz", "popcnt"}
_SKIP = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "add-dependency", "partition-id", "replica-id",
         "opt-barrier", "custom-call", "domain", "infeed", "outfeed"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+) = ")
_OP_RE = re.compile(r"^([\w\-]+)\(")


def _split_instr(line: str):
    """'%n = TYPE op(operands), attrs' -> (name, type_str, op, rest).

    Handles tuple types containing commas, layouts, and /*index=k*/ comments
    by scanning to the matching close paren."""
    nm = _NAME_RE.match(line)
    if not nm:
        return None
    rest = line[nm.end():]
    if rest.startswith("("):
        depth = 0
        idx = 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, after = rest[:idx + 1], rest[idx + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, after = rest[:sp], rest[sp + 1:].lstrip()
    om = _OP_RE.match(after)
    if not om:
        return None
    return nm.group(1), type_str, om.group(1), after[om.end():]
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\((.*)\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=(%[\w\.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+)")
_LHS_CD_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BD_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_elems_bytes(text: str) -> tuple[int, int, list[list[int]]]:
    """All shapes in ``text`` -> (total elems, total bytes, dims list)."""
    elems, nbytes, dims_all = 0, 0, []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        dd = [int(x) for x in dims.split(",") if x] or [1]
        n = 1
        for d in dd:
            n *= d
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
        dims_all.append(dd)
    return elems, nbytes, dims_all


@dataclass
class Instr:
    name: str
    op: str
    result_elems: int
    result_bytes: int
    result_dims: list
    operands: list
    attrs: str


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # name -> (elems, bytes, dims)
    params: list = field(default_factory=list)  # ordered header param names


@dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    coll_wire: float = 0.0
    coll_per_kind: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.transcendentals += other.transcendentals * times
        self.bytes += other.bytes * times
        self.coll_wire += other.coll_wire * times
        for k, v in other.coll_per_kind.items():
            self.coll_per_kind[k] = self.coll_per_kind.get(k, 0.0) + v * times
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * times


def parse_module(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        hm = _HEADER_RE.match(line.strip())
        if hm and line.rstrip().endswith("{"):
            cur = Computation(hm.group(1))
            comps[cur.name] = cur
            if line.strip().startswith("ENTRY"):
                entry = cur.name
            # header params: "%p: bf16[4,8]" -> shape table
            for pname, dt, dims in re.findall(
                    r"([\w\.\-]+): ([a-z0-9]+)\[([0-9,]*)\]", hm.group(2)):
                e, b, dd = _shape_elems_bytes(f"{dt}[{dims}]")
                cur.shapes["%" + pname] = (e, b, dd)
                cur.params.append("%" + pname)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _split_instr(line)
        if not im:
            continue
        name, rtype, op, rest = im
        e, b, dims = _shape_elems_bytes(rtype)
        # split operand list from trailing attrs at the matching paren
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        opnds = re.findall(r"%[\w\.\-]+", rest[:idx])
        attrs = rest[idx + 1:]
        cur.shapes[name] = (e, b, dims)
        cur.instrs.append(Instr(name, op, e, b, dims, opnds, attrs))
    return comps, entry


def _group_size(attrs: str) -> int:
    m = _GROUPS_RE.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    return 2


def _wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    return {"all-gather": (n - 1) / n, "reduce-scatter": float(n - 1),
            "all-reduce": 2.0 * (n - 1) / n, "all-to-all": (n - 1) / n,
            "collective-permute": 1.0}[kind]


class Analyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: dict[str, Cost] = {}

    def cost(self) -> Cost:
        return self._comp_cost(self.entry, top=True)

    def _operand_bytes(self, comp: Computation, instr: Instr) -> int:
        return sum(comp.shapes.get(o, (0, 0, []))[1] for o in instr.operands)

    _SLICE_OPS = ("dynamic-slice", "slice", "gather")

    def _fusion_operand_bytes(self, comp, instr, called) -> int:
        total = 0
        for pos, opnd in enumerate(instr.operands):
            full = comp.shapes.get(opnd, (0, 0, []))[1]
            if pos < len(called.params):
                pname = called.params[pos]
                uses = [u for u in called.instrs if pname in u.operands]
                if uses and all(u.op in self._SLICE_OPS for u in uses):
                    total += sum(u.result_bytes for u in uses)
                    continue
            total += full
        return total

    def _move_bytes(self, comp: Computation, instr: Instr) -> int:
        """HBM traffic for data-movement ops: slicing ops touch only the
        slice (in-place bufferization), not the whole operand buffer."""
        op = instr.op
        if op in ("dynamic-slice", "slice", "gather"):
            return 2 * instr.result_bytes
        if op in ("dynamic-update-slice", "scatter"):
            upd = (comp.shapes.get(instr.operands[1], (0, 0, []))[1]
                   if len(instr.operands) > 1 else instr.result_bytes)
            return 2 * upd
        if op in ("broadcast", "iota"):
            return instr.result_bytes
        if op in ("copy", "transpose", "convert", "reverse", "pad", "reshape"):
            return 2 * instr.result_bytes
        if op == "concatenate":
            return 2 * instr.result_bytes
        return instr.result_bytes + self._operand_bytes(comp, instr)

    def _comp_cost(self, name: str, top: bool = False,
                   inside_fusion: bool = False) -> Cost:
        key = (name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps[name]
        total = Cost()
        for ins in comp.instrs:
            op = ins.op
            base = op.split("-start")[0] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if base in _COLLECTIVES:
                n = _group_size(ins.attrs)
                wf = _wire_factor(base, n)
                rb = ins.result_bytes
                total.coll_wire += rb * wf
                total.coll_per_kind[base] = total.coll_per_kind.get(base, 0.0) + rb * wf
                total.coll_count[base] = total.coll_count.get(base, 0.0) + 1
                if not inside_fusion:
                    total.bytes += rb + self._operand_bytes(comp, ins)
                continue
            if op == "while":
                body = _BODY_RE.search(ins.attrs)
                trip = _TRIP_RE.search(ins.attrs)
                trips = int(trip.group(1)) if trip else 1
                if body:
                    total.add(self._comp_cost(body.group(1)), trips)
                continue
            if op == "conditional":
                brs = _BRANCHES_RE.search(ins.attrs)
                if brs:
                    costs = [self._comp_cost(b.strip())
                             for b in brs.group(1).split(",") if b.strip()]
                    if costs:
                        total.add(max(costs, key=lambda c: c.flops))
                continue
            if op in ("call", "fusion", "async-start"):
                cm = _CALLS_RE.search(ins.attrs)
                if op == "fusion":
                    # fusion: inner flops count, but memory traffic is the
                    # fusion boundary (operands + result); a fusion operand
                    # that is only ever SLICED inside contributes its slice
                    # sizes, not the whole buffer (in-place bufferization).
                    if cm:
                        inner = self._comp_cost(cm.group(1), inside_fusion=True)
                        c = Cost(flops=inner.flops,
                                 transcendentals=inner.transcendentals)
                        c.coll_wire = inner.coll_wire
                        c.coll_per_kind = dict(inner.coll_per_kind)
                        c.coll_count = dict(inner.coll_count)
                        total.add(c)
                        total.bytes += (ins.result_bytes
                                        + self._fusion_operand_bytes(
                                            comp, ins, self.comps[cm.group(1)]))
                    else:
                        total.bytes += (ins.result_bytes
                                        + self._operand_bytes(comp, ins))
                elif cm:
                    total.add(self._comp_cost(cm.group(1)))
                continue
            if op == "dot":
                lhs = comp.shapes.get(ins.operands[0], (0, 0, [[1]]))
                lhs_dims = lhs[2][0] if lhs[2] else [1]
                cds = _LHS_CD_RE.search(ins.attrs)
                contract = 1
                if cds and cds.group(1):
                    for d in cds.group(1).split(","):
                        if int(d) < len(lhs_dims):
                            contract *= lhs_dims[int(d)]
                total.flops += 2.0 * ins.result_elems * contract
                if not inside_fusion:
                    total.bytes += ins.result_bytes + self._operand_bytes(comp, ins)
                continue
            if op == "convolution":
                k = comp.shapes.get(ins.operands[1], (0, 0, [[1]]))
                kelems = k[0]
                out_feat = ins.result_dims[0][-1] if ins.result_dims else 1
                m = re.search(r"dim_labels=\S*_(\S*?)->", ins.attrs)
                # flops ≈ 2 · out_elems · (kernel elems / out_features)
                total.flops += 2.0 * ins.result_elems * max(kelems / max(out_feat, 1), 1)
                if not inside_fusion:
                    total.bytes += ins.result_bytes + self._operand_bytes(comp, ins)
                continue
            if base in _ELEMENTWISE or base in _TRANSCENDENTAL or base in _MOVE:
                if base in _ELEMENTWISE:
                    total.flops += ins.result_elems
                elif base in _TRANSCENDENTAL:
                    total.flops += ins.result_elems
                    total.transcendentals += ins.result_elems
                elif base == "reduce":
                    total.flops += self._operand_bytes(comp, ins) // 4
                if not inside_fusion:
                    total.bytes += self._move_bytes(comp, ins)
                continue
            if op in _SKIP:
                if op == "custom-call" and not inside_fusion:
                    total.bytes += ins.result_bytes + self._operand_bytes(comp, ins)
                continue
            # default: treat as data movement
            if not inside_fusion:
                total.bytes += ins.result_bytes + self._operand_bytes(comp, ins)
        self._memo[key] = total
        return total


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict across jaxlib versions (it
    returns a one-element list on jaxlib<=0.4.x)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def analyze(text: str) -> dict:
    a = Analyzer(text)
    c = a.cost()
    return {
        "flops": c.flops,
        "transcendentals": c.transcendentals,
        "bytes": c.bytes,
        "collective_wire_bytes": c.coll_wire,
        "collective_per_kind": c.coll_per_kind,
        "collective_count": c.coll_count,
    }
