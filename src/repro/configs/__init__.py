"""Assigned-architecture registry: ``--arch <id>`` resolves here."""
from . import (deepseek_moe_16b, gemma3_4b, gemma_2b, granite_moe_3b_a800m,
               hubert_xlarge, llava_next_34b, qwen3_4b, qwen3_8b, xlstm_350m,
               zamba2_7b)
from .base import SHAPES, ModelConfig, ShapeConfig  # noqa: F401

_MODULES = {
    "qwen3-4b": qwen3_4b,
    "gemma3-4b": gemma3_4b,
    "gemma-2b": gemma_2b,
    "qwen3-8b": qwen3_8b,
    "zamba2-7b": zamba2_7b,
    "xlstm-350m": xlstm_350m,
    "granite-moe-3b-a800m": granite_moe_3b_a800m,
    "deepseek-moe-16b": deepseek_moe_16b,
    "hubert-xlarge": hubert_xlarge,
    "llava-next-34b": llava_next_34b,
}

ARCHS = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _MODULES[name].reduced()


def cells():
    """All (arch, shape) dry-run cells with skip rules (DESIGN.md §5)."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if cfg.family == "audio" and shape in ("decode_32k", "long_500k"):
                continue  # encoder-only: no autoregressive step
            if shape == "long_500k" and cfg.family not in ("hybrid", "ssm"):
                continue  # needs sub-quadratic attention
            out.append((arch, shape))
    return out
