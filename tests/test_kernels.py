"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("shape", [(8, 16, 16), (24, 40, 33), (16, 8, 128),
                                   (5, 30, 17)])
@pytest.mark.parametrize("eb", [0.1, 1e-3])
def test_lorenzo_fwd_matches_ref(shape, eb):
    x = np.cumsum(RNG.standard_normal(shape), axis=0).astype(np.float32)
    d, rec = ops.lorenzo_quantize(x, eb)
    d_ref, rec_ref = ref.lorenzo3d_fwd_ref(jnp.asarray(x), eb)
    assert np.array_equal(np.asarray(d), np.asarray(d_ref))
    assert np.allclose(np.asarray(rec), np.asarray(rec_ref))


@pytest.mark.parametrize("shape", [(8, 16, 16), (12, 24, 20)])
def test_lorenzo_inverse_roundtrip(shape):
    eb = 0.01
    x = np.cumsum(RNG.standard_normal(shape), axis=1).astype(np.float32)
    d, rec = ops.lorenzo_quantize(x, eb)
    q = ops.lorenzo_dequantize(d, eb)
    # inverse reproduces the fused-kernel reconstruction
    assert np.allclose(np.asarray(q), np.asarray(rec), atol=1e-6)
    assert np.abs(np.asarray(q) - x).max() <= eb * (1 + 1e-6)


@pytest.mark.parametrize("shape", [(4, 16, 16), (16, 40, 33)])
@pytest.mark.parametrize("mode", [(True, True), (True, False), (False, False)])
def test_fused_enhance_matches_ref(shape, mode):
    regulated, strict = mode
    eb = 0.05
    z = RNG.standard_normal(shape).astype(np.float32)
    dec = RNG.standard_normal(shape).astype(np.float32)
    orig = (dec + RNG.uniform(-eb, eb, shape)).astype(np.float32)
    out, mask = ops.enhance(z, dec, orig, eb, regulated=regulated, strict=strict)
    out_r, mask_r = ref.fused_enhance_ref(jnp.asarray(z), jnp.asarray(dec),
                                          jnp.asarray(orig), eb,
                                          regulated=regulated, strict=strict)
    # 1-ulp differences possible (sigmoid fusion); mask knife-edges likewise
    assert np.allclose(np.asarray(out), np.asarray(out_r), rtol=2e-5, atol=1e-6)
    assert (np.asarray(mask) != np.asarray(mask_r)).mean() < 1e-2


def test_fused_enhance_strict_bound():
    eb = 0.05
    shape = (8, 32, 32)
    z = RNG.standard_normal(shape).astype(np.float32) * 5
    dec = RNG.standard_normal(shape).astype(np.float32)
    orig = (dec + RNG.uniform(-eb, eb, shape)).astype(np.float32)
    out, _ = ops.enhance(z, dec, orig, eb, regulated=True, strict=True)
    assert np.abs(np.asarray(out) - orig).max() <= eb * (1 + 1e-5)


@pytest.mark.parametrize("hw", [(16, 16), (24, 20), (25, 33), (31, 17)])
@pytest.mark.parametrize("cin,cout", [(1, 4), (4, 6), (8, 8), (12, 4)])
@pytest.mark.parametrize("stride", [1, 2])
def test_conv3x3_sweep(hw, cin, cout, stride):
    h, w_ = hw
    x = RNG.standard_normal((2, h, w_, cin)).astype(np.float32)
    w = (RNG.standard_normal((3, 3, cin, cout)) * 0.2).astype(np.float32)
    b = (RNG.standard_normal((cout,)) * 0.1).astype(np.float32)
    y = ops.conv3x3(x, w, b, stride=stride)
    yr = ref.conv2d3x3_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                           stride=stride)
    assert y.shape == yr.shape
    assert np.allclose(np.asarray(y), np.asarray(yr), atol=1e-5)
