"""Byte-stream codec layer: zstandard when available, stdlib zlib fallback.

Every compressed blob in the system (entropy-coded quantization streams,
enhancer weights, outlier coordinates, unpredictable masks, checkpoints)
routes through this module so that ``zstandard`` is a genuinely *optional*
dependency: a box without the wheel still produces valid archives (zlib) and
can decode any zlib-coded archive.  The codec name travels in the blob header
(``"codec"`` key) so either side can decode; legacy blobs without the key are
assumed zstd, which matches every archive written before the key existed.

Raw byte streams with no header (checkpoint files) are decoded by sniffing
the zstd frame magic — zlib streams can never start with it.
"""
from __future__ import annotations

import os
import zlib

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover - exercised on boxes without the wheel
    _zstd = None

HAVE_ZSTD = _zstd is not None
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

# Resolution order: explicit arg > set_default_codec() > $REPRO_CODEC > best.
_override: str | None = None


def available_codecs() -> tuple[str, ...]:
    return ("zstd", "zlib") if HAVE_ZSTD else ("zlib",)


def default_codec() -> str:
    name = _override or os.environ.get("REPRO_CODEC")
    if name:
        _check(name)
        return name
    return "zstd" if HAVE_ZSTD else "zlib"


def set_default_codec(name: str | None) -> None:
    """Force a codec process-wide (``None`` restores auto-selection)."""
    global _override
    if name is not None:
        _check(name)
    _override = name


def _check(name: str) -> None:
    if name not in ("zstd", "zlib"):
        raise ValueError(f"unknown codec {name!r} (want 'zstd' or 'zlib')")
    if name == "zstd" and not HAVE_ZSTD:
        raise ImportError(
            "codec 'zstd' requested but the zstandard package is not "
            "installed; pip install 'repro-neurlz[zstd]' or use codec='zlib'")


def compress(data: bytes, level: int = 9, codec: str | None = None
             ) -> tuple[bytes, str]:
    """Compress ``data``; returns ``(payload, codec_name)`` for the header."""
    name = codec or default_codec()
    _check(name)
    if name == "zstd":
        return _zstd.ZstdCompressor(level=level).compress(data), "zstd"
    return zlib.compress(data, min(level, 9)), "zlib"


def decompress(payload: bytes, codec: str = "zstd") -> bytes:
    _check(codec)
    if codec == "zstd":
        return _zstd.ZstdDecompressor().decompress(payload)
    return zlib.decompress(payload)


def decompress_sniffed(payload: bytes) -> bytes:
    """Decode a headerless stream by sniffing the zstd frame magic."""
    if payload[:4] == _ZSTD_MAGIC:
        return decompress(payload, "zstd")
    return decompress(payload, "zlib")
