"""Paper Fig 17 / §5.3: sample-conflict analysis — proportion of slice pairs
with similar inputs but dissimilar residual targets (explains why some
fields stop improving at strict bounds)."""
from __future__ import annotations

import time

import numpy as np

from . import common
from repro import compressors as C
from repro.data import fields as F


def conflict_fraction(rec, x, eb):
    d = np.moveaxis(rec.astype(np.float64), 0, 0).reshape(rec.shape[0], -1)
    r = np.moveaxis((x - rec).astype(np.float64) / eb, 0, 0).reshape(rec.shape[0], -1)

    def unit(a):
        n = np.linalg.norm(a, axis=1, keepdims=True)
        return a / np.maximum(n, 1e-30)

    du, ru = unit(d), unit(r)
    sim_x = np.abs(du @ du.T)
    sim_y = np.abs(ru @ ru.T)
    conflict = (sim_x > 0.95) & (sim_y < 0.05)
    n = conflict.shape[0]
    off = ~np.eye(n, dtype=bool)
    return float(conflict[off].mean())


def run(full: bool = False):
    shape = (32, 48, 48) if full else (24, 40, 40)
    flds = F.make_fields("nyx", shape=shape, seed=2)
    for name in ("temperature", "velocity_y"):
        x = flds[name]
        t0 = time.time()
        arc, rec = C.compress(x, 5e-5, compressor="szlike")
        frac = conflict_fraction(rec.astype(np.float64),
                                 x.astype(np.float64), arc["abs_eb"])
        common.csv_row(f"fig17/{name}", (time.time() - t0) * 1e6,
                       f"conflict_fraction={frac:.4f}")


if __name__ == "__main__":
    run()
