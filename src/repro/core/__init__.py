"""NeurLZ core — the paper's primary contribution as a composable JAX module.

Public API:
    Archive                             — handle over both archive formats
    ErrorBound                          — per-field error-bound spec
    NeurLZConfig, compress, decompress  — the enhancer pipeline
      (compress/decompress/load are legacy dict shims; prefer
       ``repro.NeurLZ`` / ``repro.Archive``)
    skipping_dnn                        — the ~3k-param enhancer network
    online_trainer                      — compression-time learning loop
    regulation                          — 1×/2× error-bound modes
    metrics                             — PSNR/MAE/DSSIM/bitrate/OLR
"""
import jax

jax.config.update("jax_enable_x64", True)  # FP64 datasets (Miranda)

from . import archive, batched_engine, bounds, conv_stage, metrics, online_trainer, regulation, skipping_dnn  # noqa: E402,F401
from .neurlz import (NeurLZConfig, assemble_streaming_archive, compress,  # noqa: E402,F401
                     decompress, field_bitrate, load, save)
from .bounds import ErrorBound, resolve_bounds  # noqa: E402,F401
from .archive_api import Archive  # noqa: E402,F401
