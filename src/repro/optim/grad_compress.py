"""Compressed cross-pod gradient synchronization with error feedback.

The multi-pod design keeps FSDP inside a pod and plain DP across pods
(DESIGN.md §6), so the inter-pod traffic is exactly one gradient all-reduce
per step — the slowest link in the system (data-center network between
pods, not ICI).  This module applies the paper's error-bounded-compression
idea to that transfer:

  * ``quantize_ef`` — per-tensor error-bounded linear quantization of the
    gradient to int8 with an *error-feedback* residual carried to the next
    step (Seide et al.; Karimireddy et al.) — unbiased over time, 4× fewer
    wire bytes than f32 / 2× fewer than bf16;
  * ``compressed_psum`` — quantize → psum (int32 accum) → dequantize, for
    use inside ``shard_map`` over the ``pod`` axis;
  * host-side NeurLZ gradient archival (``neurlz_grad_archive``) for
    debugging/async replay: full error-bounded archive of a gradient tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ef(grads, ef_state, *, bits: int = 8):
    """Error-feedback quantization.  Returns (q int8 tree, scales, new_ef).

    q = round((g + ef) / scale) with scale = max|g+ef| / qmax per tensor;
    the quantization error becomes the next step's ef carry.
    """
    qmax = float(2 ** (bits - 1) - 1)

    def one(g, ef):
        g32 = g.astype(jnp.float32) + ef
        scale = jnp.maximum(jnp.max(jnp.abs(g32)) / qmax, 1e-30)
        q = jnp.clip(jnp.round(g32 / scale), -qmax, qmax).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return q, scale, g32 - deq

    flat, treedef = jax.tree.flatten(grads)
    efs = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat, efs)]
    qs = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    new_ef = treedef.unflatten([o[2] for o in out])
    return qs, scales, new_ef


def dequantize(qs, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qs, scales)


def init_ef(grads_like):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum(grads, ef_state, axis_name: str, *, bits: int = 8):
    """Inside shard_map over ``axis_name``: error-feedback int8 all-reduce.

    Wire bytes: int8 payload + one f32 scale per tensor (vs f32/bf16 full
    gradients) — a 4×/2× collective-term reduction on the pod axis.
    Accumulation in int32 (no overflow for <=2^23 pods-worth of int8).
    """
    qs, scales, new_ef = quantize_ef(grads, ef_state, bits=bits)
    n = jax.lax.psum(1, axis_name)
    summed = jax.tree.map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), qs)
    # scales differ per pod: use the max (conservative; consistent decode)
    gmax = jax.tree.map(lambda s: jax.lax.pmax(s, axis_name), scales)
    mean = jax.tree.map(
        lambda si, s: (si.astype(jnp.float32) * s) / n, summed, gmax)
    return mean, new_ef


def bf16_psum(grads, axis_name: str):
    """Cheaper baseline: bf16 cross-pod reduce (2× wire reduction)."""
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        .astype(jnp.float32), grads)


def neurlz_grad_archive(grads, rel_eb: float = 1e-3) -> dict:
    """Host-side error-bounded archive of a gradient tree (paper pipeline
    applied to gradients; used by the grad-compression benchmark)."""
    import numpy as np

    from ..compressors import szlike

    total_raw, total_comp = 0, 0
    arcs = {}
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    for path, g in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        a = np.asarray(g, dtype=np.float32)
        if a.ndim < 2 or a.size < 1024:
            continue
        arc, _ = szlike.compress(a if a.ndim in (2, 3) else a.reshape(a.shape[0], -1),
                                 rel_eb=rel_eb,
                                 config=szlike.SZLikeConfig(predictor="lorenzo"))
        arcs[key] = arc
        total_raw += a.nbytes
        total_comp += arc["nbytes"]
    return {"arcs": arcs, "raw_bytes": total_raw, "comp_bytes": total_comp,
            "ratio": total_raw / max(total_comp, 1)}
