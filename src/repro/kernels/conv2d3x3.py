"""Fused 3×3 conv + bias + ReLU for the skipping enhancer — Pallas TPU kernel.

The enhancer's channels are tiny (4–8), so a 3×3 conv here has arithmetic
intensity ≈ 9·C_in flops/byte ≤ 72 — far below the MXU roofline knee; the op
is bandwidth-bound and the right TPU mapping is the *VPU* shifted-accumulate
form, not an im2col matmul (DESIGN.md §3, hardware adaptation).  What the
kernel buys is fusion: unfused XLA will materialize the conv output before
bias/ReLU; here one VMEM pass computes

    y = relu( Σ_{dy,dx} shift(x, dy, dx) @ W[dy,dx] + b )

with optional stride-2 decimation for the encoder stages — halving the HBM
writeback vs conv-then-slice.

Tiling: grid over the batch of slices; each step holds one full (H, W, C_in)
slice plus the (H, W, C_out) accumulator in VMEM (≤512×512×8 fp32 = 8 MB).
The 3×3 halo never crosses a block boundary because H/W are untiled.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _same_pads(size: int, stride: int) -> tuple[int, int, int]:
    """XLA SAME-padding arithmetic for a 3-tap window."""
    out = (size + stride - 1) // stride
    total = max((out - 1) * stride + 3 - size, 0)
    lo = total // 2
    return out, lo, total - lo


def _kernel(x_ref, w_ref, b_ref, y_ref, *, stride: int, relu: bool,
            pads: tuple):
    x = x_ref[...][0]          # (H, W, Cin)
    w = w_ref[...]             # (3, 3, Cin, Cout)
    b = b_ref[...]             # (Cout,)
    h, wd, cin = x.shape
    cout = w.shape[-1]
    (ho, ylo, yhi), (wo, xlo, xhi) = pads
    # SAME padding once in VMEM; then 9 shifted (H,W,Cin)x(Cin,Cout) matmuls
    # accumulated at the strided output positions directly.
    xp = jnp.pad(x, ((ylo, yhi), (xlo, xhi), (0, 0)))
    acc = jnp.zeros((ho, wo, cout), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            win = jax.lax.slice(
                xp, (dy, dx, 0),
                (dy + (ho - 1) * stride + 1, dx + (wo - 1) * stride + 1, cin),
                (stride, stride, 1))
            acc = acc + jnp.einsum("hwc,cf->hwf", win.astype(jnp.float32),
                                   w[dy, dx].astype(jnp.float32))
    acc = acc + b.astype(jnp.float32)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    y_ref[...] = acc[None].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "relu", "interpret"))
def conv2d3x3(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1,
              relu: bool = True, interpret: bool = True) -> jax.Array:
    """x: (N, H, W, Cin) fp32; w: (3, 3, Cin, Cout); b: (Cout,).
    Returns (N, H', W', Cout) with H' = ceil(H/stride)."""
    n, h, wd, cin = x.shape
    cout = w.shape[-1]
    ho, ylo, yhi = _same_pads(h, stride)
    wo, xlo, xhi = _same_pads(wd, stride)
    kernel = functools.partial(_kernel, stride=stride, relu=relu,
                               pads=((ho, ylo, yhi), (wo, xlo, xhi)))
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, wd, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((3, 3, cin, cout), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, ho, wo, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), x.dtype),
        interpret=interpret,
    )(x, w, b)
