"""NeurLZ quickstart: compress a scientific field with online neural
enhancement, decompress, verify the bound.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import core
from repro.core import metrics
from repro.data import fields

# 1. a synthetic cosmology block (stands in for a Nyx field)
flds = fields.make_fields("nyx", shape=(32, 48, 48), seed=0)
x = flds["dark_matter_density"]

# 2. compress with a strict 1e-3 value-range-relative bound; the enhancer
#    trains online for 5 epochs during compression
cfg = core.NeurLZConfig(compressor="szlike", mode="strict", epochs=5)
archive = core.compress({"dmd": x}, rel_eb=1e-3, config=cfg)

# 3. decompress and verify
out = core.decompress(archive)["dmd"]
eb = archive["fields"]["dmd"]["abs_eb"]
print(f"max |err|/eb : {np.abs(out.astype(np.float64) - x).max() / eb:.4f}  (must be <= 1)")
print(f"PSNR         : {metrics.psnr(x, out):.2f} dB")
print(f"bitrate      : {archive['bitrate']['dmd']['bitrate']:.3f} bits/value "
      f"(fp32 raw = 32)")
