"""Paper Table 2: relative bit-rate reduction (%) at equal PSNR, across
datasets x error bounds x conventional compressors."""
from __future__ import annotations

import time


from . import common
from repro.data import fields as F


def run(full: bool = False):
    shape = (64, 64, 64) if full else (24, 40, 40)
    epochs = 60 if full else 40
    bounds = [1e-2, 5e-3, 1e-3] if not full else [1e-2, 5e-3, 1e-3, 5e-4, 1e-4]
    rows = []
    for dataset in ("nyx", "miranda", "hurricane"):
        flds = F.make_fields(dataset, shape=shape, seed=2)
        names = F.DATASET_FIELDS[dataset][:2] if not full else F.DATASET_FIELDS[dataset]
        cross = F.DEFAULT_CROSS_FIELD[dataset]
        for comp in ("szlike", "zfplike"):
            for name in names:
                sub = {name: flds[name]}
                aux = [a for a in cross.get(name, ()) if a != name][:1]
                cf = {name: tuple(aux)} if aux else {}
                for a in aux:
                    sub[a] = flds[a]
                curve = common.rd_curve(flds[name], comp,
                                        [3e-2, 1e-2, 3e-3, 1e-3, 3e-4])
                for eb in bounds:
                    t0 = time.time()
                    arc, dec, out, t = common.run_neurlz(
                        sub, eb, compressor=comp, mode="strict",
                        epochs=epochs, cross_field=cf)
                    r = out[name]
                    conv_br = common.equal_psnr_bitrate(curve, r["psnr"])
                    red = 100.0 * (1.0 - r["bitrate"] / conv_br)
                    red_am = 100.0 * (1.0 - r["bitrate_amortized"] / conv_br)
                    rows.append((dataset, comp, name, eb, r["psnr"],
                                 r["bitrate"], conv_br, red, red_am))
                    common.csv_row(
                        f"table2/{dataset}/{comp}/{name}/eb{eb:g}",
                        (time.time() - t0) * 1e6,
                        f"psnr={r['psnr']:.2f};bitrate={r['bitrate']:.3f};"
                        f"conv_equal_psnr_bitrate={conv_br:.3f};"
                        f"reduction_pct={red:.1f};"
                        f"reduction_amortized_pct={red_am:.1f}")
    return rows


if __name__ == "__main__":
    run()
