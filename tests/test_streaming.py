"""Streaming snapshot pipeline: bounded-memory scheduling, bit-identity to
the serial engine, the incremental container format, chunked sources, and
one-field-at-a-time decode."""
import io
import os

import numpy as np
import pytest

from repro import core, streaming
from repro.core import archive as A
from repro.core import batched_engine
from repro.data import fields as F

FIELDS = F.make_fields("nyx", shape=(8, 16, 16), seed=7)
NAMES = list(FIELDS)


def _cfg(engine="serial", **kw):
    return core.NeurLZConfig(epochs=2, mode="strict", engine=engine, **kw)


def _serial_arc(flds, **kw):
    return core.compress(flds, rel_eb=1e-3, config=_cfg(**kw))


def _stream_to(tmp_path, flds_or_source, name="snap.nlzs", **cfg_kw):
    path = str(tmp_path / name)
    report = streaming.compress(flds_or_source, path, rel_eb=1e-3,
                                config=_cfg("streaming", **cfg_kw))
    return path, report


# ---------------------------------------------------------------------------
# Bit-identity with the in-memory serial path
# ---------------------------------------------------------------------------

def test_streamed_archive_bit_identical_to_serial(tmp_path):
    path, _ = _stream_to(tmp_path, FIELDS, group_size=1)
    arc_serial = _serial_arc(FIELDS)
    arc_stream = core.load(path)
    assert A.dumps(arc_stream["fields"]) == A.dumps(arc_serial["fields"])
    # and the whole-dict load contract matches: bitrate, compressor, axis
    assert arc_stream["compressor"] == arc_serial["compressor"]
    assert arc_stream["slice_axis"] == arc_serial["slice_axis"]
    assert arc_stream["bitrate"] == arc_serial["bitrate"]


def test_engine_streaming_through_core_compress():
    arc_serial = _serial_arc(FIELDS)
    arc_stream = core.compress(FIELDS, rel_eb=1e-3, config=_cfg("streaming"))
    assert A.dumps(arc_stream["fields"]) == A.dumps(arc_serial["fields"])
    assert "peak_resident_bytes" in arc_stream["timing"]
    assert arc_stream["timing"]["entries"] == len(FIELDS)


def test_streaming_cross_field_bit_identical(tmp_path):
    cross = F.DEFAULT_CROSS_FIELD["nyx"]
    arc_serial = core.compress(FIELDS, rel_eb=1e-3,
                               config=_cfg(cross_field=cross))
    path, _ = _stream_to(tmp_path, FIELDS, cross_field=cross, group_size=1)
    assert A.dumps(core.load(path)["fields"]) == A.dumps(arc_serial["fields"])


def test_streaming_ragged_and_order_independent(tmp_path):
    rag = {"a": FIELDS[NAMES[0]], "b": FIELDS[NAMES[1]][:5],
           "c": FIELDS[NAMES[2]]}
    arc_serial = _serial_arc(rag)
    for gs in (0, 1, 2):
        path, _ = _stream_to(tmp_path, rag, name=f"rag{gs}.nlzs",
                             group_size=gs)
        assert A.dumps(core.load(path)["fields"]) == \
            A.dumps(arc_serial["fields"])


# ---------------------------------------------------------------------------
# Bounded memory: snapshot bigger than the residency budget
# ---------------------------------------------------------------------------

def test_bigger_than_memory_snapshot_under_budget(tmp_path):
    src = streaming.synthetic_snapshot_source(12, shape=(8, 16, 16))
    flds = {n: src.load(n) for n in src.names()}
    total = sum(x.nbytes for x in flds.values())
    ws = 4 * flds[src.names()[0]].nbytes   # x + rec + inputs + targets
    budget = int(2.2 * ws)
    assert total > budget, "snapshot must exceed the budget for this test"

    path = str(tmp_path / "big.nlzs")
    sched = streaming.PipelineScheduler(
        _cfg("streaming", group_size=1, max_resident_bytes=budget))
    report = sched.run(src, path, rel_eb=1e-3)
    assert report["peak_resident_bytes"] <= budget
    assert report["entries"] == len(flds)
    # ...and still bit-identical to compressing the whole dict serially.
    arc_serial = _serial_arc(flds)
    assert A.dumps(core.load(path)["fields"]) == A.dumps(arc_serial["fields"])


def test_budget_too_small_raises_with_context(tmp_path):
    with pytest.raises(MemoryError, match="max_resident_bytes"):
        streaming.compress(
            FIELDS, str(tmp_path / "tiny.nlzs"), rel_eb=1e-3,
            config=_cfg("streaming", group_size=1, max_resident_bytes=1000))


def test_ledger_accounting():
    led = streaming.ResidencyLedger(100)
    led.add("a", 60)
    assert led.fits(40) and not led.fits(41)
    led.add("b", 40)
    assert led.peak == 100
    led.drop("a")
    assert led.current == 40
    led.drop("missing")                     # no-op
    assert led.current == 40
    assert "b" in led and "a" not in led


def test_order_groups_frees_aux_early():
    """The walk order keeps aux producer and consumer adjacent."""
    shapes = {n: (8, 16, 16) for n in ("p", "c", "u1", "u2", "u3")}
    metas = {n: streaming.FieldMeta.of(s, "float32")
             for n, s in shapes.items()}
    cfg = _cfg(cross_field={"c": ("p",)}, group_size=1)
    groups = batched_engine.plan_groups_from_meta(
        shapes, {n: 2 if n == "c" else 1 for n in shapes}, cfg)
    aux_map = {n: list(cfg.cross_field.get(n, ())) for n in shapes}
    order = streaming.order_groups(groups, aux_map, metas)
    pos = {g.names[0]: i for i, g in enumerate(order)}
    assert abs(pos["c"] - pos["p"]) == 1


# ---------------------------------------------------------------------------
# Incremental container + streaming decode
# ---------------------------------------------------------------------------

def test_container_roundtrip_and_random_access(tmp_path):
    path, report = _stream_to(tmp_path, FIELDS)
    assert A.is_streaming_archive(path)
    assert os.path.getsize(path) == report["bytes_written"]
    with A.ArchiveReader(path) as r:
        assert r.meta["field_order"] == NAMES
        # random access: read a single late entry without touching others
        entry = r.read_entry(NAMES[-1])
        assert entry["mode"] == "strict"
    assert not A.is_streaming_archive(b"not an archive")


def test_iter_decompress_matches_serial_decode(tmp_path):
    path, _ = _stream_to(tmp_path, FIELDS)
    dec_serial = core.decompress(_serial_arc(FIELDS))
    seen = []
    for name, x in streaming.iter_decompress(path):
        seen.append(name)
        assert np.array_equal(x, dec_serial[name])
    assert seen == NAMES


def test_iter_decompress_cross_field(tmp_path):
    cross = {NAMES[0]: (NAMES[1],), NAMES[2]: (NAMES[1],)}
    path, _ = _stream_to(tmp_path, FIELDS, cross_field=cross)
    dec_serial = core.decompress(
        core.compress(FIELDS, rel_eb=1e-3, config=_cfg(cross_field=cross)))
    dec_stream = streaming.decompress(path)
    for name in FIELDS:
        assert np.array_equal(dec_stream[name], dec_serial[name])


def test_in_memory_sink_bytesio():
    buf = io.BytesIO()
    streaming.compress(FIELDS, buf, rel_eb=1e-3, config=_cfg("streaming"))
    buf.seek(0)
    with A.ArchiveReader(buf) as r:
        arc = core.assemble_streaming_archive(r)
    assert A.dumps(arc["fields"]) == A.dumps(_serial_arc(FIELDS)["fields"])


# ---------------------------------------------------------------------------
# Chunked sources
# ---------------------------------------------------------------------------

def test_dict_and_function_source_metadata():
    src = streaming.as_source(FIELDS)
    assert src.names() == NAMES
    m = src.meta(NAMES[0])
    assert m.shape == (8, 16, 16)
    assert m.nbytes == FIELDS[NAMES[0]].nbytes

    lazy = streaming.synthetic_snapshot_source(5, shape=(8, 16, 16))
    assert len(lazy.names()) == 5
    # naming parity with the eager benchmark helper
    from benchmarks import common
    eager = common.snapshot_fields(5, shape=(8, 16, 16))
    assert lazy.names() == list(eager)
    for n in lazy.names():
        assert np.array_equal(lazy.load(n), eager[n])
        assert lazy.load(n).nbytes == lazy.meta(n).nbytes


def test_npy_dir_source_streams_bit_identical(tmp_path):
    d = tmp_path / "npys"
    d.mkdir()
    for n, x in FIELDS.items():
        np.save(str(d / f"{n}.npy"), x)
    src = streaming.as_source(str(d))
    assert src.names() == sorted(NAMES)
    assert isinstance(src.load(NAMES[0]), np.memmap)
    path, _ = _stream_to(tmp_path, src)
    arc_serial = _serial_arc({n: FIELDS[n] for n in sorted(NAMES)})
    assert A.dumps(core.load(path)["fields"]) == A.dumps(arc_serial["fields"])


def test_blocked_source_splits_and_reassembles(tmp_path):
    big = F.make_fields("nyx", shape=(16, 16, 16), seed=1)["temperature"]
    base = streaming.DictSource({"huge": big})
    bsrc = streaming.BlockedSource(base, max_block_bytes=big.nbytes // 3)
    man = bsrc.manifest["huge"]
    assert [b[0] for b in man["blocks"]] == bsrc.names()
    assert sum(hi - lo for _, lo, hi in man["blocks"]) == big.shape[0]

    path, _ = _stream_to(tmp_path, bsrc, group_size=1)
    arc = core.load(path)
    # block entries == serial compression of the pre-split snapshot
    presplit = {bn: np.ascontiguousarray(big[lo:hi])
                for bn, lo, hi in man["blocks"]}
    arc_serial = _serial_arc(presplit)
    assert A.dumps(arc["fields"]) == A.dumps(arc_serial["fields"])
    # decode reassembles the original field under every block's bound
    dec = streaming.decompress(path)
    assert list(dec) == ["huge"]
    assert dec["huge"].shape == big.shape
    max_eb = max(arc["fields"][bn]["abs_eb"] for bn, _, _ in man["blocks"])
    err = np.abs(dec["huge"].astype(np.float64) - big.astype(np.float64))
    assert float(err.max()) <= max_eb


def test_blocked_source_leaves_small_fields_alone():
    src = streaming.BlockedSource(streaming.DictSource(FIELDS),
                                  max_block_bytes=10 * 2**20)
    assert src.names() == NAMES
    assert src.manifest == {}
    assert np.array_equal(src.load(NAMES[0]), FIELDS[NAMES[0]])


def test_as_source_rejects_garbage():
    with pytest.raises(TypeError):
        streaming.as_source(42)


# ---------------------------------------------------------------------------
# Async writer
# ---------------------------------------------------------------------------

def test_writer_thread_error_surfaces(tmp_path):
    cfg = _cfg("streaming")
    w = streaming.AsyncArchiveWriter(str(tmp_path / "x.nlzs"), cfg)
    w.put(streaming.EntryTask(name="f", conv_arc={}, params=None, stats=[],
                              aux=[], eb=1.0, net_cfg=None, history=[],
                              mask=None))
    with pytest.raises(RuntimeError, match="archive writer thread failed"):
        w.close({"field_order": ["f"]})


def test_batched_on_entry_callback():
    seen = []
    arc = batched_engine.compress(
        FIELDS, 1e-3, config=_cfg("batched", group_size=1),
        on_entry=lambda name, entry: seen.append(name))
    assert sorted(seen) == sorted(NAMES)
    assert A.dumps(arc["fields"]) == A.dumps(_serial_arc(FIELDS)["fields"])
