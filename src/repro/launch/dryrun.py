import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell against the production mesh and record memory / cost / collective
numbers for the roofline analysis (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --cell neurlz_enhance
Options: --multi-pod / --single-pod (default: both), --out experiments/dryrun,
         --remat {nothing,dots}, --seq-shard (sequence parallelism).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from .. import configs  # noqa: E402
from ..configs.base import SHAPES  # noqa: E402
from ..distributed import sharding as sh  # noqa: E402
from ..models import model as M  # noqa: E402
from . import hlo_cost  # noqa: E402
from . import roofline as rl  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402


def _jsonable(d):
    if isinstance(d, dict):
        return {k: _jsonable(v) for k, v in d.items()}
    if isinstance(d, (list, tuple)):
        return [_jsonable(v) for v in d]
    if hasattr(d, "item"):
        return d.item()
    return d


def lower_cell(arch: str, shape_name: str, mesh, *, remat: str = "nothing",
               seq_shard: bool = False, donate: bool = True,
               microbatch: int = 4, skip_uncausal: bool = False,
               moe_group: int | None = None, sp_residual: bool = False):
    """Lower + compile one cell; returns the record dict."""
    import dataclasses
    cfg = configs.get_config(arch)
    if skip_uncausal:
        cfg = dataclasses.replace(cfg, attn_skip_uncausal=True)
    if moe_group is not None:
        cfg = dataclasses.replace(cfg, moe_group_size=moe_group)
    if sp_residual:
        cfg = dataclasses.replace(cfg, sp_residual=True)
    shape = SHAPES[shape_name]
    model_axis = mesh.shape["model"]
    n_chips = int(jax.device_count()) if False else 1
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    model = M.build_model(cfg, model_axis=model_axis)

    abs_params = M.abstract_params(model)
    pspecs = sh.param_pspecs(abs_params, mesh)
    params_ns = sh.to_named(pspecs, mesh)
    abs_params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_params, params_ns)

    specs = M.input_specs(cfg, shape)
    in_specs = sh.input_pspecs(specs, mesh, seq_shard=seq_shard)
    in_ns = {k: jax.sharding.NamedSharding(mesh, v) for k, v in in_specs.items()}
    batch_abs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=in_ns[k])
                 for k, v in specs.items()}

    t0 = time.time()
    sh.set_active_mesh(mesh)
    with mesh:
        if shape.kind == "train":
            abs_opt = M.abstract_opt_state(abs_params)
            opt_specs = sh.opt_pspecs(pspecs)
            opt_ns = sh.to_named(opt_specs, mesh)
            abs_opt = jax.tree.map(
                lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
                abs_opt, opt_ns)
            step_fn = M.make_train_step(model, remat_policy=remat,
                                        microbatch=microbatch)
            jitted = jax.jit(
                step_fn,
                in_shardings=(params_ns, opt_ns, in_ns, None),
                out_shardings=(params_ns, opt_ns, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(abs_params, abs_opt, batch_abs,
                                   jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            fn = (M.make_encode_step(model) if cfg.family == "audio"
                  else M.make_prefill_step(model, remat_policy=remat))
            jitted = jax.jit(fn, in_shardings=(params_ns, in_ns))
            lowered = jitted.lower(abs_params, batch_abs)
        else:  # decode
            abs_cache = M.abstract_cache(model, shape.global_batch, shape.seq_len)
            cache_specs = sh.cache_pspecs(abs_cache, mesh, shape.global_batch)
            cache_ns = sh.to_named(cache_specs, mesh)
            abs_cache = jax.tree.map(
                lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
                abs_cache, cache_ns)
            step_fn = M.make_decode_step(model)
            jitted = jax.jit(
                step_fn,
                in_shardings=(params_ns, cache_ns, in_ns["tokens"], None),
                out_shardings=(None, cache_ns),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(abs_params, abs_cache, batch_abs["tokens"],
                                   jax.ShapeDtypeStruct((), jnp.int32))
        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1
    sh.set_active_mesh(None)

    ma = compiled.memory_analysis()
    ca = hlo_cost.xla_cost_dict(compiled)
    hlo = hlo_cost.analyze(compiled.as_text())   # loop-aware per-device cost
    flops = hlo["flops"]
    bytes_acc = hlo["bytes"]
    coll = {"wire_bytes": hlo["collective_wire_bytes"],
            "per_kind_wire": hlo["collective_per_kind"],
            "per_kind_count": hlo["collective_count"]}
    terms = rl.roofline_terms(flops, bytes_acc, coll["wire_bytes"])
    mflops = rl.model_flops(cfg, shape, n_chips)

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "n_chips": n_chips,
        "kind": shape.kind, "remat": remat, "seq_shard": seq_shard,
        "microbatch": microbatch if shape.kind == "train" else None,
        "skip_uncausal": skip_uncausal, "moe_group": moe_group,
        "sp_residual": sp_residual,
        "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_hbm_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        },
        "cost": {"flops_per_device": flops, "bytes_per_device": bytes_acc,
                 "transcendentals": hlo["transcendentals"],
                 "xla_flops_loops_once": float(ca.get("flops", 0.0)),
                 "xla_bytes_loops_once": float(ca.get("bytes accessed", 0.0))},
        "collectives": coll,
        "roofline": terms,
        "model_flops_per_device": mflops,
        "useful_compute_ratio": (mflops / flops) if flops else None,
        "n_active_params": cfg.n_active_params(),
        "n_params": cfg.n_params_estimate(),
    }
    return record


def lower_neurlz_enhance(mesh, *, n_blocks: int = 512, side: int = 512,
                         batch_slices: int = 10):
    """The paper-technique cell: pod-scale batched online enhancer training.

    One train step for ``n_blocks`` per-block skipping-DNN enhancers at once
    (vmap over blocks; blocks sharded over every mesh axis) — the TPU-native
    reformulation of the paper's per-block GPU loop (DESIGN.md §3).
    """
    from ..core import skipping_dnn  # enables x64 (compressor stack) ...
    jax.config.update("jax_enable_x64", False)  # ... switch it back off

    net_cfg = skipping_dnn.SkippingDNNConfig(c_in=2)  # cross-field channels
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v

    def one_block_step(params, opt, xb, yb):
        from ..optim import adamw_update

        def loss_fn(p):
            pred = skipping_dnn.forward(p, xb, regulated=True, skip=True)
            return jnp.mean(jnp.square(pred - yb))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt = adamw_update(grads, opt, params, lr=1e-2)
        return params, opt, loss

    def train_step(params_stack, opt_stack, inputs, targets):
        p, o, losses = jax.vmap(one_block_step)(params_stack, opt_stack,
                                                inputs, targets)
        loss = jnp.mean(losses)
        try:  # under shard_map: global mean (the run's only collective)
            loss = jax.lax.pmean(loss, tuple(mesh.shape.keys()))
        except NameError:
            pass
        return p, o, loss

    def init_all():
        from ..optim import adamw_init
        keys = jax.random.split(jax.random.PRNGKey(0), n_blocks)
        params = jax.vmap(lambda k: skipping_dnn.init_params(k, net_cfg))(keys)
        return params, jax.vmap(lambda _: adamw_init(
            skipping_dnn.init_params(jax.random.PRNGKey(0), net_cfg)))(
                jnp.arange(n_blocks))

    abs_ps, abs_opt = jax.eval_shape(init_all)
    every = tuple(mesh.shape.keys())
    block_spec = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(every))

    def shard_stack(tree):
        return jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=block_spec),
            tree)

    abs_ps, abs_opt = shard_stack(abs_ps), shard_stack(abs_opt)
    xin = jax.ShapeDtypeStruct((n_blocks, batch_slices, side, side, 2),
                               jnp.float32, sharding=block_spec)
    yin = jax.ShapeDtypeStruct((n_blocks, batch_slices, side, side, 1),
                               jnp.float32, sharding=block_spec)

    # Per-block training is embarrassingly parallel: shard_map over every
    # mesh axis pins the block dim per-device (plain pjit replicated the
    # conv activations -> 227 GiB/device, §Perf iteration C0->C1).
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    spec = P(every)
    smapped = shard_map(train_step, mesh=mesh,
                        in_specs=(spec, spec, spec, spec),
                        out_specs=(spec, spec, P()), check_rep=False)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(smapped, donate_argnums=(0, 1))
        lowered = jitted.lower(abs_ps, abs_opt, xin, yin)
        compiled = lowered.compile()
    ma = compiled.memory_analysis()
    hlo = hlo_cost.analyze(compiled.as_text())
    flops = hlo["flops"]
    bytes_acc = hlo["bytes"]
    coll = {"wire_bytes": hlo["collective_wire_bytes"],
            "per_kind_wire": hlo["collective_per_kind"],
            "per_kind_count": hlo["collective_count"]}
    return {
        "arch": "neurlz_enhance", "shape": f"{n_blocks}x{side}x{side}",
        "mesh": dict(mesh.shape), "n_chips": n_chips, "kind": "train",
        "compile_s": round(time.time() - t0, 1),
        "memory": {"argument_bytes": ma.argument_size_in_bytes,
                   "temp_bytes": ma.temp_size_in_bytes,
                   "peak_hbm_bytes": ma.argument_size_in_bytes + ma.temp_size_in_bytes},
        "cost": {"flops_per_device": flops, "bytes_per_device": bytes_acc},
        "collectives": coll,
        "roofline": rl.roofline_terms(flops, bytes_acc, coll["wire_bytes"]),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--cell", default=None, help="special cell: neurlz_enhance")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--remat", default="nothing", choices=["nothing", "dots"])
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--microbatch", type=int, default=4)
    ap.add_argument("--skip-uncausal", action="store_true")
    ap.add_argument("--moe-group", type=int, default=None,
                    help="override MoE routing group size (perf lever)")
    ap.add_argument("--sp-residual", action="store_true",
                    help="sequence-parallel residual stream (perf lever)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists with status ok")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.multi_pod or not args.single_pod:
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    cells = []
    if args.cell == "neurlz_enhance":
        cells = [("neurlz_enhance", None)]
    elif args.all:
        cells = configs.cells() + [("neurlz_enhance", None)]
    elif args.arch:
        shapes = [args.shape] if args.shape else [
            s for a, s in configs.cells() if a == args.arch]
        cells = [(args.arch, s) for s in shapes]
    else:
        ap.error("pass --all, --arch, or --cell")

    failures = 0
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            tag = f"{arch}_{shape or 'na'}_{mesh_name}" + (
                f"_{args.tag}" if args.tag else "")
            path = os.path.join(args.out, tag + ".json")
            if args.resume and os.path.exists(path):
                try:
                    with open(path) as f:
                        if json.load(f).get("status") == "ok":
                            print(f"=== {tag} === (cached)", flush=True)
                            continue
                except Exception:
                    pass
            print(f"=== {tag} ===", flush=True)
            try:
                if arch == "neurlz_enhance":
                    rec = lower_neurlz_enhance(mesh)
                else:
                    rec = lower_cell(arch, shape, mesh, remat=args.remat,
                                     seq_shard=args.seq_shard,
                                     microbatch=args.microbatch,
                                     skip_uncausal=args.skip_uncausal,
                                     moe_group=args.moe_group,
                                     sp_residual=args.sp_residual)
                rec["status"] = "ok"
                r = rec["roofline"]
                print(f"  compile={rec.get('compile_s', '?')}s "
                      f"peak_hbm={rec['memory']['peak_hbm_bytes']/2**30:.2f}GiB "
                      f"compute={r['compute_s']*1e3:.2f}ms "
                      f"memory={r['memory_s']*1e3:.2f}ms "
                      f"coll={r['collective_s']*1e3:.2f}ms "
                      f"dominant={r['dominant']}", flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures += 1
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "fail", "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
            with open(path, "w") as f:
                json.dump(_jsonable(rec), f, indent=1)
    print(f"done; failures={failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
