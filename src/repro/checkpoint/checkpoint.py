"""Fault-tolerant checkpointing.

Design (for 1000+ node runs):
  * **atomic** — write to ``step_N.tmp/`` then ``rename``; a crash mid-save
    never corrupts the latest checkpoint;
  * **manifest** — ``manifest.json`` lists steps; ``latest_step()`` is what
    restart reads; retention keeps the newest K;
  * **self-describing** — params/opt-state stored as a flat {path: array}
    msgpack+zstd blob with dtype/shape, so a checkpoint written on one mesh
    restores onto ANY other mesh (elastic re-sharding = load + device_put
    with the new sharding — see ``repro.distributed.elastic``);
  * **NeurLZ-compressed mode** — the paper's technique applied to the
    framework's own state: weights go through the error-bounded pipeline
    (strict 1× bound on every weight), cutting checkpoint bytes by ~2–4×
    at eb=1e-5 rel; optimizer moments, being noise-like, stay lossless.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import msgpack
import numpy as np

from ..compressors import codec


def _flatten(tree, prefix="", out=None):
    import jax

    out = {} if out is None else out
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[prefix + key] = np.asarray(leaf)
    return out


def _unflatten_into(template, flat, prefix=""):
    import jax
    import jax.numpy as jnp

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    new = []
    for path, leaf in paths_leaves:
        key = prefix + "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                                for p in path)
        arr = flat[key]
        new.append(jnp.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, new)


def _pack_arrays(flat: dict, level: int = 3, lossy_eb: float | None = None) -> bytes:
    entries = {}
    for k, a in flat.items():
        a = np.ascontiguousarray(a)
        if lossy_eb is not None and a.dtype in (np.float32, np.float64) and a.ndim >= 2:
            # NeurLZ error-bounded weight compression (strict 1x bound).
            from ..compressors import szlike

            arc, _ = szlike.compress(
                a if a.ndim in (2, 3) else a.reshape(a.shape[0], -1),
                rel_eb=lossy_eb,
                config=szlike.SZLikeConfig(predictor="lorenzo"))
            entries[k] = {"kind": "szlike", "arc": _arc_to_bytes(arc),
                          "shape": list(a.shape), "dtype": str(a.dtype)}
        else:
            entries[k] = {"kind": "raw", "dtype": str(a.dtype),
                          "shape": list(a.shape), "data": a.tobytes()}
    payload = msgpack.packb(entries, use_bin_type=True)
    return codec.compress(payload, level)[0]


def _arc_to_bytes(arc: dict) -> bytes:
    return msgpack.packb(arc, use_bin_type=True, default=lambda o: o.item()
                         if hasattr(o, "item") else o)


def _unpack_arrays(data: bytes) -> dict:
    # Checkpoint blobs are headerless; the codec is sniffed from the stream
    # (zstd frame magic vs zlib), so checkpoints move between installs.
    payload = codec.decompress_sniffed(data)
    entries = msgpack.unpackb(payload, raw=False, strict_map_key=False)
    out = {}
    for k, e in entries.items():
        if e.get("kind", "raw") == "szlike":
            from ..compressors import szlike

            arc = msgpack.unpackb(e["arc"], raw=False, strict_map_key=False)
            arr = szlike.decompress(arc)
            out[k] = arr.reshape(e["shape"]).astype(e["dtype"])
        else:
            out[k] = np.frombuffer(e["data"], dtype=e["dtype"]).reshape(e["shape"])
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 lossy_weights_eb: float | None = None):
        self.dir = directory
        self.keep = keep
        self.lossy_eb = lossy_weights_eb
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, params, opt_state=None, extra: dict | None = None):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        t0 = time.time()
        with open(os.path.join(tmp, "params.bin"), "wb") as f:
            f.write(_pack_arrays(_flatten(params), lossy_eb=self.lossy_eb))
        if opt_state is not None:
            with open(os.path.join(tmp, "opt.bin"), "wb") as f:
                f.write(_pack_arrays(_flatten(opt_state)))
        meta = {"step": int(step), "time": time.time(),
                "save_seconds": time.time() - t0,
                "lossy_weights_eb": self.lossy_eb,
                "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic publish
        self._update_manifest(step)
        self._retain()
        return final

    def _update_manifest(self, step: int):
        man = self.manifest()
        if step not in man["steps"]:
            man["steps"].append(int(step))
            man["steps"].sort()
        tmp = os.path.join(self.dir, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(man, f)
        os.replace(tmp, os.path.join(self.dir, "manifest.json"))

    def _retain(self):
        man = self.manifest()
        while len(man["steps"]) > self.keep:
            victim = man["steps"].pop(0)
            path = os.path.join(self.dir, f"step_{victim}")
            if os.path.exists(path):
                shutil.rmtree(path)
        tmp = os.path.join(self.dir, "manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(man, f)
        os.replace(tmp, os.path.join(self.dir, "manifest.json"))

    # --------------------------------------------------------------- restore
    def manifest(self) -> dict:
        path = os.path.join(self.dir, "manifest.json")
        if not os.path.exists(path):
            return {"steps": []}
        with open(path) as f:
            return json.load(f)

    def latest_step(self) -> int | None:
        steps = self.manifest()["steps"]
        # tolerate a manifest entry whose directory was lost (partial node
        # failure): fall back to the newest complete checkpoint
        for s in sorted(steps, reverse=True):
            if os.path.exists(os.path.join(self.dir, f"step_{s}", "meta.json")):
                return s
        return None

    def restore(self, step: int, params_template, opt_template=None):
        base = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(base, "params.bin"), "rb") as f:
            params = _unflatten_into(params_template, _unpack_arrays(f.read()))
        opt = None
        if opt_template is not None:
            with open(os.path.join(base, "opt.bin"), "rb") as f:
                opt = _unflatten_into(opt_template, _unpack_arrays(f.read()))
        with open(os.path.join(base, "meta.json")) as f:
            meta = json.load(f)
        return params, opt, meta
