"""Production mesh construction (assignment spec §MULTI-POD DRY-RUN).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def mesh_kwargs(num_axes: int) -> dict:
    """``axis_types`` kwarg for ``jax.make_mesh`` on jax versions that have
    it (``jax.sharding.AxisType`` landed after 0.4.x); empty dict before."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_kwargs(len(axes)))


def make_host_mesh():
    """Single-device mesh for CPU smoke tests (model_axis=1)."""
    return jax.make_mesh((1, 1), ("data", "model"), **mesh_kwargs(2))
