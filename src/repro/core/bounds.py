"""Per-field error-bound specs (NeurLZ §3.1: *user-input* error bounds).

The paper frames NeurLZ as a service: each field of a snapshot arrives with
its own user-chosen bound and leaves with a strictly regulated
reconstruction.  :class:`ErrorBound` is that spec — a value-range-relative
bound (``rel``), an absolute bound (``abs``), and an optional per-field
regulation ``mode`` (strict 1× / relaxed 2× / unregulated) that overrides
the session default.

Everything downstream threads these specs instead of one scalar ``rel_eb``:
the conventional stage groups fields by ``(shape, dtype, bound)`` so fields
sharing a spec still batch through the fused compressor entries
(:mod:`repro.core.conv_stage`), the engines derive each field's enhancer
regulation from its own resolved mode, and every archive entry records the
absolute bound it actually honored (``entry["abs_eb"]`` / ``entry["mode"]``
— exactly as before, which is what keeps mixed-bound archives decodable by
the unchanged per-entry decode path).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

MODES = ("strict", "relaxed", "unregulated")


@dataclasses.dataclass(frozen=True)
class ErrorBound:
    """One field's user-input error-bound spec.

    ``rel``
        value-range-relative bound: the absolute bound becomes
        ``rel * (max - min)`` of the field (the paper's default notion).
    ``abs``
        absolute bound; takes precedence over ``rel`` when both are set
        (matching the compressor entry points' ``abs_eb`` precedence).
    ``mode``
        per-field regulation mode (``"strict"`` / ``"relaxed"`` /
        ``"unregulated"``) or ``None`` to inherit the session default.
    """

    rel: float | None = None
    abs: float | None = None
    mode: str | None = None

    def __post_init__(self):
        if self.mode is not None and self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r} (want one of {MODES})")
        for k in ("rel", "abs"):
            v = getattr(self, k)
            if v is not None and not float(v) > 0.0:
                raise ValueError(f"ErrorBound.{k} must be > 0, got {v!r}")

    @property
    def specified(self) -> bool:
        return self.rel is not None or self.abs is not None

    def resolved(self, default_mode: str) -> "ErrorBound":
        """Concrete spec: mode filled in from the session default."""
        if not self.specified:
            raise ValueError("ErrorBound needs rel= or abs=")
        if self.mode is not None:
            return self
        return dataclasses.replace(self, mode=default_mode)

    def conv_key(self) -> tuple:
        """Hashable grouping key for the conventional stage: fields whose
        specs agree here may compress through one fused batched dispatch
        (mode does not touch the conventional stage, so it is excluded)."""
        return (self.rel, self.abs)

    def limit(self, abs_eb: float) -> float:
        """The verification ceiling this spec promises for a field whose
        derived absolute bound is ``abs_eb`` (1× strict, 2× relaxed,
        unbounded for the unregulated ablation)."""
        if self.mode == "relaxed":
            return 2.0 * abs_eb
        if self.mode == "unregulated":
            return float("inf")
        return abs_eb


def as_bound(spec) -> ErrorBound:
    """Coerce a user spec: ErrorBound passes through, a bare number is a
    value-range-relative bound (the historical ``rel_eb`` meaning)."""
    if isinstance(spec, ErrorBound):
        return spec
    if isinstance(spec, (int, float)):
        return ErrorBound(rel=float(spec))
    raise TypeError(f"cannot interpret {type(spec).__name__} as an ErrorBound "
                    "(want ErrorBound or a relative-bound number)")


def resolve_bounds(names, bounds, rel_eb=None, abs_eb=None, *,
                   default_mode: str = "strict"
                   ) -> dict[str, ErrorBound]:
    """Resolve per-field specs for every field of a snapshot.

    ``bounds`` may be ``None`` (every field uses ``rel_eb``/``abs_eb``), one
    spec applied to all fields, or a mapping ``name -> spec`` whose missing
    names fall back to ``rel_eb``/``abs_eb``.  Specs may be
    :class:`ErrorBound` instances or bare numbers (relative bounds).  Every
    returned spec is concrete (mode filled in); a field with no resolvable
    bound is a hard error.
    """
    default = ErrorBound(rel=rel_eb, abs=abs_eb) \
        if (rel_eb is not None or abs_eb is not None) else None
    out: dict[str, ErrorBound] = {}
    if bounds is None:
        per_field: Mapping = {}
        fallback = default
    elif isinstance(bounds, Mapping):
        per_field = bounds
        unknown = [n for n in bounds if n not in set(names)]
        if unknown:
            raise KeyError(f"bounds given for unknown fields {unknown}")
        fallback = default
    else:
        per_field = {}
        fallback = as_bound(bounds)
    for name in names:
        spec = as_bound(per_field[name]) if name in per_field else fallback
        if spec is None or not spec.specified:
            raise ValueError(f"no error bound for field {name!r}: pass "
                             "rel_eb/abs_eb or a bounds entry for it")
        out[name] = spec.resolved(default_mode)
    return out
