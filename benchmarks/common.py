"""Shared benchmark plumbing: NeurLZ-vs-conventional runs, rate-distortion
interpolation (the paper's 'bit-rate reduction at equal PSNR'), CSV output.

Default scales are CPU-sized (small blocks, few epochs); pass ``--full`` to
``benchmarks.run`` for paper-scale settings.
"""
from __future__ import annotations

import time

import numpy as np

from repro import compressors as C
from repro import core
from repro.core import metrics
from repro.core import neurlz
from repro.data import fields as F


def rd_curve(x, compressor: str, bounds) -> list[tuple[float, float]]:
    """Conventional rate-distortion curve: [(psnr, bitrate bits/val)]."""
    pts = []
    for eb in bounds:
        arc, _ = C.compress(x, eb, compressor=compressor)
        dec = C.decompress(arc)
        pts.append((metrics.psnr(x, dec), 8.0 * arc["nbytes"] / x.size))
    return sorted(pts)


def equal_psnr_bitrate(curve, psnr: float) -> float:
    """Conventional bitrate needed to reach ``psnr`` (log-rate interp)."""
    ps = np.array([p for p, _ in curve])
    bs = np.array([b for _, b in curve])
    return float(np.exp(np.interp(psnr, ps, np.log(bs))))


def run_neurlz(fields_dict, rel_eb, *, compressor="szlike", mode="strict",
               epochs=5, cross_field=None, **kw):
    cfg = core.NeurLZConfig(compressor=compressor, mode=mode, epochs=epochs,
                            cross_field=cross_field or {}, **kw)
    t0 = time.time()
    arc = neurlz.compress_impl(fields_dict, rel_eb=rel_eb, config=cfg)
    t_comp = time.time() - t0
    t1 = time.time()
    dec = neurlz.decompress_impl(arc)
    t_dec = time.time() - t1
    out = {}
    for name, x in fields_dict.items():
        br = arc["bitrate"][name]
        # Paper accounting: the enhancer weights amortize over the paper's
        # 512^3 runtime blocks; on CPU-sized test blocks we report both the
        # full-weight bitrate (honest at this block size) and the amortized
        # one (the paper's operating point).
        amort = 8.0 * (br["conv_bytes"] + br["outlier_bytes"]
                       + br["weight_bytes"] * x.size / 512**3) / x.size
        out[name] = {
            "psnr": metrics.psnr(x, dec[name]),
            "mae": metrics.mae(x, dec[name]),
            "bitrate": arc["bitrate"][name]["bitrate"],
            "bitrate_amortized": amort,
            "conv_bitrate": arc["bitrate"][name]["conv_bitrate"],
            "max_err_over_eb": float(
                np.abs(dec[name].astype(np.float64)
                       - x.astype(np.float64)).max()
                / arc["fields"][name]["abs_eb"]),
            "olr_bits": arc["fields"][name].get("outliers", {}).get(
                "packed_bits", 0),
        }
    return arc, dec, out, {"compress_s": t_comp, "decompress_s": t_dec}


# Ledger registry: every csv_row lands here too, so ``benchmarks.run``
# can persist a machine-readable run record (BENCH_PR7.json) that
# ``scripts/perf_summary.py --compare`` diffs across commits.
ROWS: list[dict] = []


def _parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived strings -> dict (floats where they parse)."""
    out: dict = {}
    for part in str(derived).split(";"):
        k, sep, v = part.partition("=")
        if not sep:
            if part.strip():
                out.setdefault("notes", []).append(part.strip())
            continue
        v = v.strip()
        try:
            out[k.strip()] = float(v)
        except ValueError:
            out[k.strip()] = v
    return out


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    ROWS.append({"name": name, "us_per_call": float(us_per_call),
                 "derived": _parse_derived(derived)})


def peak_rss_bytes() -> int:
    """Process-lifetime peak RSS (monotonic — use deltas across phases with
    care; the streaming benchs report it alongside the pipeline's own
    residency-ledger peak, which is the budgeted quantity)."""
    import resource
    import sys
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return int(ru if sys.platform == "darwin" else ru * 1024)


def writer_overlap(report: dict) -> float:
    """Fraction of async-writer busy time hidden behind compute.

    Non-overlapped writer work is time the pipeline spent *blocked on the
    writer*: the ``close`` drain tail plus back-pressure stalls inside
    ``put`` (full bounded queue); everything else of ``writer_busy_s`` ran
    concurrently with training/prefetch."""
    busy = float(report.get("writer_busy_s", 0.0))
    if busy <= 0.0:
        return 1.0
    stalled = (float(report.get("writer_close_wait_s", 0.0))
               + float(report.get("writer_put_wait_s", 0.0)))
    return 1.0 - min(busy, stalled) / busy


def bench_fields(dataset="nyx", shape=(32, 48, 48), seed=2):
    return F.make_fields(dataset, shape=shape, seed=seed)


def snapshot_fields(num_fields: int, shape=(16, 32, 32), dataset="nyx"):
    """A multi-field snapshot with ``num_fields`` fields (multiple correlated
    blocks when the dataset has fewer native fields) — the batched engine's
    unit of work."""
    out = {}
    seed = 2
    while len(out) < num_fields:
        for name, x in F.make_fields(dataset, shape=shape, seed=seed).items():
            if len(out) < num_fields:
                out[f"{name}_s{seed}"] = x
        seed += 1
    return out


def timed_compress(fields_dict, rel_eb, cfg, repeats: int = 3):
    """Best-of-``repeats`` wall-clock for the compression engine (first call
    outside the timer warms the jit caches)."""
    neurlz.compress_impl(fields_dict, rel_eb=rel_eb, config=cfg)
    best, arc = float("inf"), None
    for _ in range(repeats):
        t0 = time.time()
        arc = neurlz.compress_impl(fields_dict, rel_eb=rel_eb, config=cfg)
        best = min(best, time.time() - t0)
    return best, arc
