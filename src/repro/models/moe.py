"""Mixture-of-Experts with GShard-style dispatch/combine einsums.

The canonical TPU formulation: tokens are routed in groups; a one-hot
dispatch tensor [G, S, E, C] scatters tokens to per-expert capacity slots,
expert FFNs run as one batched einsum over the expert dim, and a combine
tensor (dispatch weighted by router probs) gathers results back.  Under the
production mesh the expert dim is sharded over ``model`` (expert
parallelism) and groups over (pod, data) — the dispatch/combine einsums
lower to the all-to-all pattern the roofline analysis tracks.

Supports fine-grained MoE (DeepSeekMoE: small d_ff_expert, many experts,
shared experts always on) and top-k with capacity dropping; ``n_pad``
extends the expert dim to a multiple of the mesh axis with never-routed
experts (router logits −inf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import activation, dense_init
from . import mlp as mlp_mod


def padded_experts(n_experts: int, model_axis: int) -> int:
    return int(np.ceil(n_experts / model_axis) * model_axis)


def init(key, cfg, dtype, model_axis: int = 16):
    e_pad = padded_experts(cfg.n_experts, model_axis)
    d, f = cfg.d_model, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    s = 1.0 / np.sqrt(d)
    p = {
        "router_in": dense_init(ks[0], d, e_pad, jnp.float32),
        "w_experts_gate": (jax.random.normal(ks[1], (e_pad, d, f), jnp.float32) * s).astype(dtype),
        "w_experts_up": (jax.random.normal(ks[2], (e_pad, d, f), jnp.float32) * s).astype(dtype),
        "w_experts_down": (jax.random.normal(ks[3], (e_pad, f, d), jnp.float32)
                           * (1.0 / np.sqrt(f))).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_mod.init(ks[4], d, cfg.n_shared_experts * f, dtype)
    return p


def forward(p, cfg, x, *, model_axis: int = 16):
    """x: [B, S, D] -> [B, S, D].  Aux losses returned for load balance."""
    b, s, d = x.shape
    e_pad = p["router_in"].shape[-1]
    g_sz = min(cfg.moe_group_size, s)
    assert (b * s) % g_sz == 0, (b, s, g_sz)
    g = (b * s) // g_sz
    xt = x.reshape(g, g_sz, d)

    logits = (xt.astype(jnp.float32) @ p["router_in"])          # [G, S, Epad]
    if e_pad > cfg.n_experts:
        pad_mask = jnp.arange(e_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)

    cap = int(g_sz * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    cap = max(cap, cfg.top_k)

    topv, topi = jax.lax.top_k(probs, cfg.top_k)                # [G, S, K]
    topv = topv / (topv.sum(-1, keepdims=True) + 1e-9)          # renormalize

    # Capacity assignment: position of each (token, k) within its expert's
    # queue, computed with a cumulative count over the flattened (S*K) order.
    onehot = jax.nn.one_hot(topi, e_pad, dtype=jnp.float32)     # [G,S,K,E]
    flat = onehot.reshape(g, s_k := g_sz * cfg.top_k, e_pad)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                  # [G,S*K,E]
    pos = (pos_in_e * flat).sum(-1).reshape(g, g_sz, cfg.top_k)  # [G,S,K]
    keep = pos < cap
    pos = jnp.minimum(pos, cap - 1).astype(jnp.int32)

    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)        # [G,S,K,C]
    disp = jnp.einsum("gske,gskc,gsk->gsec", onehot, pos_oh,
                      keep.astype(jnp.float32))                 # [G,S,E,C]
    comb = jnp.einsum("gsec,gsk,gske->gsec", disp, topv, onehot)

    # Expert compute: [G,S,E,C] x [G,S,D] -> [E, G*C', D] batched FFN.
    xe = jnp.einsum("gsec,gsd->egcd", disp.astype(x.dtype), xt)  # [E,G,C,D]
    act = activation(cfg.act)
    h = act(jnp.einsum("egcd,edf->egcf", xe, p["w_experts_gate"]))
    h = h * jnp.einsum("egcd,edf->egcf", xe, p["w_experts_up"])
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_experts_down"])    # [E,G,C,D]
    y = jnp.einsum("gsec,egcd->gsd", comb.astype(x.dtype), ye)   # [G,S,D]
    out = y.reshape(b, s, d)

    if cfg.n_shared_experts:
        out = out + mlp_mod.forward(p["shared"], x, cfg.act)

    # Switch-style load-balance aux loss.
    me = probs.mean(axis=(0, 1))
    fe = onehot.sum(2).mean(axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(me * fe)
    return out, aux
