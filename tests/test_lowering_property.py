"""Property-based bit-stability: for random fields/bounds, archives under
``lowering="jit"`` are byte-identical to ``lowering="eager"`` on every
engine (the kernel-dispatch parity contract, end to end)."""
import dataclasses
import pickle
import warnings

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import neurlz  # noqa: E402

warnings.simplefilter("ignore", DeprecationWarning)


def _mk_fields(seed):
    rng = np.random.default_rng(seed)
    shape = tuple(int(s) for s in rng.integers(6, 13, size=3))
    out = {}
    for i in range(2):
        x = rng.standard_normal(shape)
        if seed % 3 == 0:   # spiky fields stress the outlier/escape paths
            x[tuple(rng.integers(0, s) for s in shape)] *= 100.0
        out[f"f{i}"] = np.cumsum(x, axis=0).astype(np.float32)
    return out


def _entries(fields, config, eb):
    if config.engine == "streaming":
        from repro.streaming import pipeline
        arc = pipeline.compress_dict(fields, eb, config=config)
    else:
        arc = neurlz.compress_impl(fields, eb, config=config)
    return pickle.dumps(arc["fields"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from([1e-2, 1e-3]),
       st.sampled_from(["serial", "batched", "streaming"]),
       st.sampled_from(["szlike", "szlike-lorenzo", "zfplike"]),
       st.sampled_from(["strict", "relaxed"]))
def test_jit_archives_byte_identical_to_eager(seed, eb, engine, compressor,
                                              mode):
    fields = _mk_fields(seed)
    cfg = neurlz.NeurLZConfig(engine=engine, compressor=compressor,
                              mode=mode, epochs=2, group_size=0)
    eager = _entries(fields, dataclasses.replace(cfg, lowering="eager"), eb)
    jit = _entries(fields, dataclasses.replace(cfg, lowering="jit"), eb)
    assert jit == eager
