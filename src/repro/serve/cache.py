"""Ledger-charged LRU cache of hot decoded fields and aux closures.

The serving tier keeps recently decoded arrays resident so repeat reads
of a hot field skip disk and decode entirely — but "resident" bytes must
answer to the **same** :class:`~repro.streaming.pipeline.ResidencyLedger`
the streaming engine charges, so one process-wide ceiling governs encode,
decode and cache together.  Every cached value is charged under a
``cache:`` key; insertion evicts least-recently-used *unpinned* values
until the ledger says the newcomer fits, and refuses to cache (rather
than evict pinned work or blow the ceiling) when it cannot.

Pinning is the aux-refcount contract from the ISSUE: while a decode that
depends on a cached aux closure is in flight, the server holds a pin on
that entry and :meth:`HotFieldCache.put`'s eviction scan skips it — a
closure is never dropped out from under a dependent decode.
"""
from __future__ import annotations

import threading
from collections import OrderedDict

from ..obs import telemetry as obs_lib


def _nbytes(value) -> int:
    """Resident-byte estimate for a cached value (array or list/tuple of
    arrays — aux closures cache as the list of reconstructions)."""
    if isinstance(value, (list, tuple)):
        return int(sum(_nbytes(v) for v in value))
    return int(getattr(value, "nbytes", 0))


class HotFieldCache:
    """LRU over decoded arrays, bytes charged to a shared ledger.

    Keys are arbitrary hashables (the server uses ``(kind, name, roi)``
    tuples).  All methods are thread-safe; values are returned as-is
    (callers must treat cached arrays as immutable — the server hands out
    copies at its boundary).
    """

    def __init__(self, ledger, telemetry=None, *, prefix: str = "cache"):
        self.ledger = ledger
        self.tel = telemetry if telemetry is not None else obs_lib.NULL
        self._prefix = prefix
        self._lock = threading.RLock()
        self._data: OrderedDict = OrderedDict()   # key -> value (LRU order)
        self._pins: dict = {}                     # key -> refcount

    def _ledger_key(self, key) -> str:
        return f"{self._prefix}:{key!r}"

    # -- lookup -------------------------------------------------------------

    def get(self, key, default=None):
        """Return the cached value (marking it most-recently-used) or
        ``default``; counts a ``serve.cache.hits`` / ``.misses``."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.tel.counter("serve.cache.hits").add()
                return self._data[key]
        self.tel.counter("serve.cache.misses").add()
        return default

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._data

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    @property
    def keys(self) -> list:
        with self._lock:
            return list(self._data)

    @property
    def resident_bytes(self) -> int:
        """Bytes this cache currently charges to the ledger."""
        with self._lock:
            return sum(_nbytes(v) for v in self._data.values())

    # -- insertion / eviction ----------------------------------------------

    def put(self, key, value) -> bool:
        """Cache ``value`` under ``key``; returns True when it ends up
        resident.  Evicts unpinned LRU entries until the ledger accepts the
        bytes; a value that still does not fit (ceiling smaller than the
        value, or everything else pinned) is simply not cached — the
        ceiling is never exceeded and pinned entries never evicted."""
        nbytes = _nbytes(value)
        with self._lock:
            if key in self._data:       # replace: drop old charge first
                self._evict(key, count=False)
            while not self.ledger.fits(nbytes):
                victim = next((k for k in self._data
                               if not self._pins.get(k)), None)
                if victim is None:
                    self.tel.counter("serve.cache.rejected").add()
                    return False
                self._evict(victim)
            self._data[key] = value
            self._data.move_to_end(key)
            self.ledger.add(self._ledger_key(key), nbytes)
            return True

    def _evict(self, key, *, count: bool = True) -> None:
        self._data.pop(key, None)
        self.ledger.drop(self._ledger_key(key))
        if count:
            self.tel.counter("serve.cache.evictions").add()

    def invalidate(self, key) -> None:
        """Drop one entry (no-op when absent; pins do not protect against
        an explicit invalidation — they only guard LRU eviction)."""
        with self._lock:
            if key in self._data:
                self._evict(key, count=False)
            self._pins.pop(key, None)

    def clear(self) -> None:
        with self._lock:
            for key in list(self._data):
                self._evict(key, count=False)
            self._pins.clear()

    # -- pinning ------------------------------------------------------------

    def pin(self, key) -> None:
        """Protect ``key`` from LRU eviction (refcounted; pairs with
        :meth:`unpin`).  Pinning a key that is not cached is allowed — the
        pin applies if it arrives later within the same hold."""
        with self._lock:
            self._pins[key] = self._pins.get(key, 0) + 1

    def unpin(self, key) -> None:
        with self._lock:
            n = self._pins.get(key, 0) - 1
            if n <= 0:
                self._pins.pop(key, None)
            else:
                self._pins[key] = n

    def pinned(self, key) -> bool:
        with self._lock:
            return bool(self._pins.get(key))

    def __repr__(self) -> str:
        with self._lock:
            return (f"<HotFieldCache entries={len(self._data)} "
                    f"pinned={sum(1 for k in self._data if self._pins.get(k))} "
                    f"bytes={self.resident_bytes}>")
