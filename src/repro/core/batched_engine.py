"""Batched multi-field NeurLZ compression engine.

The serial engine trains one field's enhancer at a time, synchronously: one
jitted dispatch per epoch *per field* with a host sync after every epoch to
collect the loss, and the CPU-side conventional compressor runs strictly
before any training starts.  Real deployments compress many fields of the
same snapshot at once (the paper's cross-field design assumes they are
resident together), so this engine restructures the hot path around the
*snapshot*:

  * **Field groups** — fields whose slice geometry and channel count match
    are planned into groups (``NeurLZConfig.group_size`` caps fields per
    group to tune the pipeline depth).  Slice-count-ragged groups are
    handled natively: each field scans its own step count inside the shared
    dispatch.
  * **Fused training dispatch** (``field_batching="unroll"``) — *every
    epoch of every field of a group* runs in a single jitted ``lax.scan``
    dispatch.  Each field's scan body is exactly
    :func:`repro.core.online_trainer.scan_train` — the serial trace — so
    trained weights, archives and reconstructions are **bit-identical** to
    the serial engine.
  * **``field_batching="vmap"``** — per-field params are stacked on a
    leading ``F`` axis (:func:`repro.core.skipping_dnn.stack_params`) and
    each epoch runs as one ``jax.vmap``-over-fields ``lax.scan``; the
    stacked axis can be sharded across devices
    (:func:`repro.distributed.sharding.field_sharding`,
    ``field_shard=True``).  The skipping-DNN forward is built from
    shift-and-accumulate ``lax.dot_general`` contractions that lower
    identically under ``vmap`` (see :mod:`repro.core.skipping_dnn`), so
    equal-slice-count groups are bit-identical to serial at most training
    signatures (XLA:CPU can still partition a gradient GEMM differently
    at some sizes); ragged fields train the padded step count per epoch
    with modulo-resampled slices and diverge from the serial trajectory
    (error-bound guarantees are unaffected either way).
  * **``field_batching="auto"`` (default)** — per group: the stacked
    ``vmap`` path for multi-field groups with matching slice counts,
    *verified* by a cached per-signature byte-parity probe
    (:func:`vmap_bit_parity`) before use; ``unroll`` for ragged or
    single-field groups, or when the probe finds the stacked gradient is
    not bit-identical (:func:`resolve_batching`).  The default therefore
    always round-trips byte-identical to serial.
  * **Async pipeline** — training *and* inference for every group are
    dispatched before any result is awaited, so the device queue never
    drains; the host meanwhile runs the *next* groups' conventional
    compression and dataset construction, with ``jax.device_put`` moving
    tensors early so upload overlaps compute.  With more than one device,
    the conventional compressor's jitted stages run on the last device so
    they never queue behind training (``prefetch=True``).
  * **Batched inference** — encode- and decode-side ``predict_residual``
    for a whole group run in one dispatch.  Inference always uses the exact
    per-field graph regardless of the training strategy, so the
    encoder-side reconstruction used for strict-mode outlier capture is
    always reproducible by any decoder: archives stay bit-compatible.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from functools import partial
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np

from .. import faults as faults_lib
from ..compressors import registry
from ..distributed import sharding as shardlib
from ..obs import telemetry as obs_lib
from ..optim import adamw_init, adamw_update, cosine_schedule
from . import bounds as bounds_lib
from . import conv_stage as conv_stage_lib
from . import neurlz, online_trainer, skipping_dnn


# ---------------------------------------------------------------------------
# Group planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FieldGroup:
    names: list[str]                 # fields, input order
    slice_hw: tuple[int, int]        # per-slice spatial shape
    c_in: int                        # input channels (1 + aux fields)
    mode: str | None = None          # per-field regulation mode override
    #   (None -> the session config's mode; groups are mode-homogeneous so
    #   one group shares one network signature / outlier-capture rule)


def group_config(config, group: FieldGroup):
    """Effective :class:`NeurLZConfig` for one group under its per-field
    regulation-mode override (identity for legacy single-mode runs)."""
    return neurlz.field_config(config, group.mode)


def sliced_shape(shape: tuple, slice_axis: int) -> tuple:
    """``np.moveaxis(x, slice_axis, 0).shape`` from the shape alone (no
    array needed — the streaming planner works off source metadata)."""
    axis = slice_axis % len(shape)
    return (shape[axis],) + tuple(s for i, s in enumerate(shape) if i != axis)


def plan_groups_from_meta(shapes: Mapping[str, tuple],
                          c_ins: Mapping[str, int],
                          config,
                          modes: Mapping[str, str] | None = None
                          ) -> list[FieldGroup]:
    """Group-plan from field *metadata* only (shapes + channel counts).

    This is the plan export used by the streaming scheduler, which must
    plan a snapshot bigger than memory before loading any field data.
    ``modes`` optionally carries per-field regulation modes (the
    :class:`repro.core.bounds.ErrorBound` overrides): fields only share a
    group when their modes agree, since a group shares one network
    signature (regulated flag) and one outlier-capture rule.
    """
    groups: dict[tuple, FieldGroup] = {}
    for name, shape in shapes.items():
        sshape = sliced_shape(tuple(shape), config.slice_axis)
        mode = modes.get(name) if modes is not None else None
        key = (sshape[1:], c_ins[name], mode)
        if key not in groups:
            groups[key] = FieldGroup(names=[], slice_hw=tuple(sshape[1:]),
                                     c_in=c_ins[name], mode=mode)
        groups[key].names.append(name)
    out = []
    for g in groups.values():
        size = config.group_size if config.group_size > 0 else len(g.names)
        for i in range(0, len(g.names), size):
            out.append(FieldGroup(names=g.names[i:i + size],
                                  slice_hw=g.slice_hw, c_in=g.c_in,
                                  mode=g.mode))
    return out


def plan_groups(fields: Mapping[str, np.ndarray], config,
                modes: Mapping[str, str] | None = None) -> list[FieldGroup]:
    """Group fields by slice geometry, channel count and regulation mode.

    A group is the unit of batched dispatch: every field in it shares the
    jitted graph's spatial/channel signature.  Slice *counts* may differ
    within a group (ragged path).  ``config.group_size > 0`` chunks groups
    to that many fields, trading per-dispatch batching for pipeline overlap
    of conventional compression with training.
    """
    shapes = {name: np.asarray(x).shape for name, x in fields.items()}
    c_ins = {name: 1 + len(neurlz._aux_names(config, name, fields))
             for name in fields}
    return plan_groups_from_meta(shapes, c_ins, config, modes=modes)


# ---------------------------------------------------------------------------
# Batched dispatches
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("spec", "epochs", "base_lr", "min_lr_frac",
                                   "loss", "lowering"))
def _train_group_fused(params_t, opt_t, xs_t, ys_t, base_key, *, spec, epochs,
                       base_lr, min_lr_frac, loss, lowering="auto"):
    """All epochs of every field of a group in ONE dispatch.

    ``spec`` is a static tuple of per-field
    ``(steps, batch, total_steps, regulated, skip)``; per-field tensors ride
    in tuples (slice counts may differ).  Per-epoch batch matrices come from
    :func:`online_trainer.epoch_batches` with the same folded keys as the
    serial trainer, and each field scans
    :func:`online_trainer.scan_train` — the serial trace — which makes this
    engine bit-identical to the serial one.  Returns per-epoch mean losses
    ``[epochs, F]``.
    """
    new_p, new_o, losses = [], [], []
    for f, (steps, batch, total_steps, reg, skip) in enumerate(spec):
        n = xs_t[f].shape[0]
        batches = jnp.concatenate([
            online_trainer.epoch_batches(jax.random.fold_in(base_key, e),
                                         n, steps, batch)
            for e in range(epochs)], axis=0)        # [epochs*steps, batch]
        p, o, lvals = online_trainer.scan_train(
            params_t[f], opt_t[f], xs_t[f], ys_t[f], batches,
            jnp.asarray(0, jnp.int32), cfg_reg=reg, cfg_skip=skip,
            total_steps=total_steps, base_lr=base_lr,
            min_lr_frac=min_lr_frac, loss=loss, lowering=lowering)
        new_p.append(p)
        new_o.append(o)
        losses.append(jnp.mean(lvals.reshape(epochs, steps), axis=1))
    return tuple(new_p), tuple(new_o), jnp.stack(losses, axis=1)


@partial(jax.jit, static_argnames=("steps", "batch", "total_steps", "reg",
                                   "skip", "base_lr", "min_lr_frac", "loss",
                                   "lowering"))
def _epoch_vmapped(params_st, opt_st, xs, ys, epoch_key, start_step,
                   n_valid, *, steps, batch, total_steps, reg, skip,
                   base_lr, min_lr_frac, loss, lowering="auto"):
    """One epoch as a single ``jax.vmap``-over-fields ``lax.scan``.

    ``xs``/``ys`` are padded to the group's max slice count ``[F,N,H,W,C]``
    and every field runs ``steps`` (the padded count's) steps per epoch;
    ``n_valid`` maps the shared per-epoch permutation into each ragged
    field's own valid range (short fields resample slices modulo their
    count), so the cosine horizon ``total_steps`` is shared and static.
    """
    n_pad = xs.shape[1]
    batches = online_trainer.epoch_batches(epoch_key, n_pad, steps, batch)
    lr_fn = cosine_schedule(base_lr, total_steps, min_lr_frac)

    def loss_fn(p, xb, yb):
        return online_trainer.batch_loss(p, xb, yb, regulated=reg, skip=skip,
                                         loss=loss, lowering=lowering)

    def body(carry, idx):
        p, o, step = carry

        def field_step(p_f, o_f, x_f, y_f, nv):
            idx_f = idx % nv
            xb = jnp.take(x_f, idx_f, axis=0)
            yb = jnp.take(y_f, idx_f, axis=0)
            lval, grads = jax.value_and_grad(loss_fn)(p_f, xb, yb)
            p_f, o_f = adamw_update(grads, o_f, p_f, lr=lr_fn(step))
            return p_f, o_f, lval

        p, o, lvals = jax.vmap(field_step)(p, o, xs, ys, n_valid)
        return (p, o, step + 1), lvals

    (params_st, opt_st, _), losses = jax.lax.scan(
        body, (params_st, opt_st, start_step), batches)
    return params_st, opt_st, jnp.mean(losses, axis=0)


@partial(jax.jit, static_argnames=("spec", "lowering"))
def _predict_group(params_t, xs_t, *, spec, lowering="auto"):
    """Batched ``predict_residual``: every field of a group, one dispatch.

    Always the exact per-field inference graph
    (:func:`online_trainer.predict_graph`), so encode- and decode-side
    reconstructions match the serial engine bit-for-bit regardless of the
    training strategy.
    """
    return tuple(
        online_trainer.predict_graph(params_t[f], xs_t[f], regulated=reg,
                                     skip=skip, lowering=lowering)
        for f, (reg, skip) in enumerate(spec))


# ---------------------------------------------------------------------------
# Group state through the pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _GroupState:
    group: FieldGroup
    net_cfg: skipping_dnn.SkippingDNNConfig
    inputs: list       # per-field device arrays [N_f, H, W, C]
    targets: list
    stats: list        # per-field normalization stats
    params: tuple      # per-field trees (device; lazy while training runs)
    opt: tuple
    steps: list        # per-field steps/epoch
    batch: list        # per-field batch size
    total_steps: list  # per-field cosine horizon
    losses: object = None   # device [epochs, F] once training is dispatched
    resids: tuple = ()      # per-field lazy [N, H, W] residual predictions


def _prepare_group(group: FieldGroup, fields, recs, ebs, config, tcfg,
                   device=None) -> _GroupState:
    """Host-side stage: datasets + async device upload + param init.

    ``device`` pins the whole group (unroll-mode field sharding: groups are
    round-robined over devices, and jit runs each group's program where its
    operands live — identical programs, so results stay bit-identical)."""
    config = group_config(config, group)
    net_cfg = config.net_config(group.c_in)
    inputs, targets, stats = [], [], []
    steps, batches, totals = [], [], []
    for name in group.names:
        x = np.asarray(fields[name])
        aux = [recs[a] for a in neurlz._aux_names(config, name, fields)]
        inp, tgt, st = neurlz.build_dataset(x, recs[name], ebs[name], aux,
                                            config)
        n = inp.shape[0]
        b = min(tcfg.batch, n)
        s = max(1, n // b)
        steps.append(s)
        batches.append(b)
        totals.append(s * tcfg.epochs)
        # device_put is async: upload overlaps earlier groups' training.
        inputs.append(jax.device_put(inp, device))
        targets.append(jax.device_put(tgt, device))
        stats.append(st)
    key = jax.random.PRNGKey(tcfg.seed)
    params = tuple(jax.device_put(skipping_dnn.init_params(key, net_cfg),
                                  device)
                   for _ in group.names)
    opt = tuple(adamw_init(p) for p in params)
    return _GroupState(group=group, net_cfg=net_cfg, inputs=inputs,
                       targets=targets, stats=stats, params=params, opt=opt,
                       steps=steps, batch=batches, total_steps=totals)


def resolve_batching(strategy: str, slice_counts: list[int]) -> str:
    """Structural strategy choice for one group.

    ``auto`` proposes the stacked ``vmap`` path for multi-field groups
    whose slice counts match; ragged groups (and single-field ones, where
    stacking buys nothing) unroll — the vmap path would train them on the
    padded step count with modulo-resampled slices, which diverges from
    the serial trajectory.  An ``auto``-proposed vmap is additionally
    gated by :func:`vmap_bit_parity` in :func:`_dispatch_group` before it
    is used (verified, not assumed — same contract as the kernel-lowering
    dispatch).
    """
    if strategy != "auto":
        return strategy
    uniform = len(set(slice_counts)) == 1
    return "vmap" if uniform and len(slice_counts) > 1 else "unroll"


# (slice_hw, c_in, batch, regulated, skip, loss, lowering) -> bool
_vmap_parity: dict[tuple, bool] = {}


def vmap_bit_parity(net_cfg, slice_hw: tuple, batch: int, tcfg) -> bool:
    """Byte-parity probe for the stacked vmap strategy at one training
    signature.

    The fast shift-and-accumulate forward lowers identically under
    ``jax.vmap`` for most shapes, but XLA:CPU may partition a *gradient*
    contraction differently between the single and the batched GEMM at
    some sizes, reassociating the reduction.  Lowered code is
    shape-dependent, not value-dependent, so one byte-compare of
    ``value_and_grad`` on canary inputs — per (spatial, channels, batch,
    loss) signature, cached — decides whether the stacked path is
    bit-identical to the per-field trace here.
    """
    key = (tuple(slice_hw), net_cfg.c_in, batch, net_cfg.regulated,
           net_cfg.skip, tcfg.loss, tcfg.lowering)
    if key in _vmap_parity:
        return _vmap_parity[key]
    h, w = slice_hw
    kp = jax.random.PRNGKey(0)
    params = skipping_dnn.init_params(kp, net_cfg)
    k1, k2 = jax.random.split(jax.random.fold_in(kp, 1))
    xs = jax.random.normal(k1, (2, batch, h, w, net_cfg.c_in), jnp.float32)
    ys = jnp.clip(jax.random.normal(k2, (2, batch, h, w, 1), jnp.float32),
                  -1.0, 1.0)

    def loss_fn(p, xb, yb):
        return online_trainer.batch_loss(
            p, xb, yb, regulated=net_cfg.regulated, skip=net_cfg.skip,
            loss=tcfg.loss, lowering=tcfg.lowering)

    singles = [jax.jit(jax.value_and_grad(loss_fn))(params, xs[i], ys[i])
               for i in range(2)]
    pst = skipping_dnn.stack_params([params, params])
    lv, gv = jax.jit(jax.vmap(jax.value_and_grad(loss_fn)))(pst, xs, ys)
    ok = True
    for i, (l1, g1) in enumerate(singles):
        if np.asarray(l1).tobytes() != np.asarray(lv[i]).tobytes():
            ok = False
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gv)):
            if np.asarray(a).tobytes() != np.asarray(b[i]).tobytes():
                ok = False
    _vmap_parity[key] = ok
    return ok


def _dispatch_group(state: _GroupState, config, tcfg) -> None:
    """Enqueue the group's full training AND inference without blocking."""
    net_cfg = state.net_cfg
    key = jax.random.PRNGKey(tcfg.seed)
    strategy = resolve_batching(config.field_batching,
                                [int(x.shape[0]) for x in state.inputs])
    if strategy == "vmap" and config.field_batching == "auto":
        n_max = max(int(x.shape[0]) for x in state.inputs)
        if not vmap_bit_parity(net_cfg, state.group.slice_hw,
                               min(tcfg.batch, n_max), tcfg):
            strategy = "unroll"
    if strategy == "vmap":
        _dispatch_vmapped(state, config, tcfg, key)
    elif strategy == "unroll":
        if tcfg.epochs <= 0:
            state.losses = jnp.zeros((0, len(state.group.names)), jnp.float32)
        else:
            spec = tuple((state.steps[f], state.batch[f],
                          state.total_steps[f], net_cfg.regulated,
                          net_cfg.skip)
                         for f in range(len(state.group.names)))
            state.params, state.opt, state.losses = _train_group_fused(
                state.params, state.opt, tuple(state.inputs),
                tuple(state.targets), key, spec=spec, epochs=tcfg.epochs,
                base_lr=tcfg.lr, min_lr_frac=tcfg.min_lr_frac,
                loss=tcfg.loss, lowering=tcfg.lowering)
    else:
        raise ValueError(f"unknown field_batching {config.field_batching!r} "
                         "(want 'auto', 'unroll' or 'vmap')")
    # Inference consumes the (still lazy) trained params — queues right
    # behind training on the device, before any host sync.
    pspec = tuple((net_cfg.regulated, net_cfg.skip)
                  for _ in state.group.names)
    state.resids = _predict_group(tuple(state.params), tuple(state.inputs),
                                  spec=pspec, lowering=tcfg.lowering)


def _dispatch_vmapped(state: _GroupState, config, tcfg, key) -> None:
    """vmap strategy: stack fields, pad ragged slice counts, train stacked."""
    net_cfg = state.net_cfg
    n_max = max(int(x.shape[0]) for x in state.inputs)
    b = min(tcfg.batch, n_max)
    steps = max(1, n_max // b)

    def pad(a):
        short = n_max - a.shape[0]
        return a if short == 0 else jnp.pad(
            a, ((0, short),) + ((0, 0),) * (a.ndim - 1))

    xs = jnp.stack([pad(x) for x in state.inputs])
    ys = jnp.stack([pad(y) for y in state.targets])
    params_st = skipping_dnn.stack_params(list(state.params))
    opt_st = jax.tree.map(lambda *a: jnp.stack(a), *state.opt)
    n_valid = jnp.asarray([x.shape[0] for x in state.inputs], jnp.int32)
    if config.field_shard:
        mesh = shardlib.field_mesh()
        if mesh is not None:
            xs = shardlib.shard_fields(xs, mesh)
            ys = shardlib.shard_fields(ys, mesh)
            params_st = shardlib.shard_fields(params_st, mesh)
            opt_st = shardlib.shard_fields(opt_st, mesh)
    losses = []
    for e in range(tcfg.epochs):
        ekey = jax.random.fold_in(key, e)
        start = jnp.asarray(e * steps, jnp.int32)
        params_st, opt_st, mloss = _epoch_vmapped(
            params_st, opt_st, xs, ys, ekey, start, n_valid,
            steps=steps, batch=b, total_steps=steps * tcfg.epochs,
            reg=net_cfg.regulated, skip=net_cfg.skip,
            base_lr=tcfg.lr, min_lr_frac=tcfg.min_lr_frac, loss=tcfg.loss,
            lowering=tcfg.lowering)
        losses.append(mloss)
    state.losses = jnp.stack(losses) if losses else \
        jnp.zeros((0, len(state.group.names)), jnp.float32)
    state.params = tuple(
        skipping_dnn.unstack_params(params_st, len(state.group.names)))
    state.opt = tuple(jax.tree.map(lambda a, i=i: a[i], opt_st)
                      for i in range(len(state.group.names)))


def group_results(state: _GroupState):
    """Sync point: block on the group's training/inference and yield
    ``(f, name, history, resid)`` per field — shared by this engine's
    finalize and the streaming pipeline's (which defers packing to the
    writer thread)."""
    history = np.asarray(state.losses)          # blocks on training
    for f, name in enumerate(state.group.names):
        yield (f, name, [float(v) for v in history[:, f]],
               np.asarray(state.resids[f]))


def _finalize_group(state: _GroupState, fields, recs, ebs, conv_arcs, config,
                    collect_stats, out_fields, on_entry=None,
                    tel=obs_lib.NULL, fc=faults_lib.DEFAULT,
                    degraded=None) -> None:
    """Blocking stage: fetch residuals, enhancement, entry packing.

    A per-field enhancer failure (injected fault at ``train.<name>``,
    non-finite loss, OOM in enhancement) degrades that field to a conv-only
    entry — same normalized reason and entry bytes as the serial engine —
    instead of aborting the snapshot."""
    config = group_config(config, state.group)
    with tel.span("finalize", group=",".join(state.group.names)):
        for f, name, hist, resid in group_results(state):
            x = np.asarray(fields[name])
            aux_names = neurlz._aux_names(config, name, fields)
            entry, reason = None, None
            try:
                fc.check(f"train.{name}")
                if fc.degrade and not neurlz.history_is_finite(hist):
                    reason = faults_lib.degrade_reason()
                else:
                    entry = neurlz.pack_entry(
                        config, conv_arcs[name], state.params[f],
                        state.stats[f], aux_names, ebs[name], state.net_cfg,
                        hist, collect_stats)
                    neurlz.finalize_entry(entry, x, recs[name], resid,
                                          ebs[name], state.stats[f], config)
            except Exception as exc:
                if not (fc.degrade and faults_lib.is_degradable(exc)):
                    raise
                reason = faults_lib.degrade_reason(exc)
            if reason is not None:
                entry = neurlz.pack_degraded_entry(config, conv_arcs[name],
                                                   ebs[name], reason)
                if degraded is not None:
                    degraded.append(name)
                tel.counter("faults.degraded").add()
            elif tel.enabled and tel.config.learning_traces:
                obs_lib.learning_trace(
                    tel, name, hist, eb=ebs[name],
                    vrange=neurlz.field_vrange(x),
                    base_bytes=neurlz.entry_base_bytes(entry),
                    n_points=int(x.size), mode=config.mode)
            out_fields[name] = entry
            if on_entry is not None:
                on_entry(name, entry)


# ---------------------------------------------------------------------------
# Engine entry points
# ---------------------------------------------------------------------------

def _conv_device():
    """Device for the conventional compressor's jitted stages: the last one,
    so they never queue behind enhancer training on device 0."""
    devs = jax.devices()
    return devs[-1] if len(devs) > 1 else None


def compress(fields: Mapping[str, np.ndarray], rel_eb: float | None = None, *,
             abs_eb: float | None = None, config=None,
             collect_stats: bool = True, on_entry=None, bounds=None) -> dict:
    """Batched-engine compression; same archive contract as the serial path.

    ``on_entry(name, entry)`` fires as each field's archive entry completes
    (groups finalize as soon as the next group is dispatched, not at end of
    run), which lets callers archive incrementally and bounds how many
    groups' tensors stay resident at once.  ``bounds`` carries per-field
    :class:`repro.core.bounds.ErrorBound` specs; groups are planned
    mode-homogeneous so each fused dispatch keeps one network signature.
    """
    config = config or neurlz.NeurLZConfig(engine="batched")
    tel = obs_lib.of(config)
    fc = faults_lib.of(config)
    t0 = time.time()
    with tel.span("compress", root=True, engine="batched",
                  fields=len(fields)):
        tcfg = config.train_config()
        resolved = None
        if bounds is not None:
            resolved = bounds_lib.resolve_bounds(list(fields), bounds,
                                                 rel_eb, abs_eb,
                                                 default_mode=config.mode)
        modes = ({n: b.mode for n, b in resolved.items()}
                 if resolved is not None else None)
        groups = plan_groups(fields, config, modes=modes)

        conv_arcs, recs, ebs = {}, {}, {}
        conv_dev = _conv_device() if config.prefetch else None
        # Shared conventional stage: each call batches the handed fields by
        # (shape, dtype, bound spec) through the fused compressor entry.
        stage = conv_stage_lib.ConvStage(config.compressor, rel_eb, abs_eb,
                                         batch=config.conv_batch,
                                         bounds=resolved, telemetry=tel,
                                         lowering=config.lowering)

        def conv_compress(names):
            todo = {n: fields[n] for n in names if n not in conv_arcs}
            if not todo:
                return
            ctx = jax.default_device(conv_dev) if conv_dev is not None \
                else contextlib.nullcontext()
            with ctx:
                for name, (arc, rec) in stage.run(todo).items():
                    conv_arcs[name], recs[name], ebs[name] = \
                        arc, rec, arc["abs_eb"]

        # Cross-field aux may reference fields in later groups; resolve the
        # whole conventional stage upfront in that case.  Otherwise it runs
        # lazily per group, overlapping earlier groups' device-side training.
        if config.cross_field or not config.prefetch:
            conv_compress(list(fields))

        # Unroll-mode field sharding: spread groups across training devices —
        # all but the conventional-compressor device, so conv work never
        # shares a queue with enhancer training.
        train_devs = jax.devices()
        if conv_dev is not None and len(train_devs) > 1:
            train_devs = train_devs[:-1]
        t_train0 = time.time()
        conv_before = stage.stats.conv_s
        # Per-group completion: finalize a group as soon as enough later
        # groups are dispatched to keep every training device's queue
        # non-empty (depth >= devices + 1), instead of holding all groups'
        # tensors until an end-of-run finalize pass.
        depth = max(2, len(train_devs) + 1)
        out_fields: dict = {}
        degraded: list[str] = []
        states: list[_GroupState] = []
        for gi, group in enumerate(groups):
            conv_compress(group.names)
            counts = [sliced_shape(np.asarray(fields[n]).shape,
                                   config.slice_axis)[0]
                      for n in group.names]
            strategy = resolve_batching(config.field_batching, counts)
            dev = train_devs[gi % len(train_devs)] \
                if (config.field_shard and len(train_devs) > 1
                    and strategy == "unroll") else None
            with tel.span("train", group=",".join(group.names)):
                state = _prepare_group(group, fields, recs, ebs, config,
                                       tcfg, device=dev)
                _dispatch_group(state, config, tcfg)   # async: no host sync
            states.append(state)
            if len(states) >= depth:
                _finalize_group(states.pop(0), fields, recs, ebs, conv_arcs,
                                config, collect_stats, out_fields, on_entry,
                                tel=tel, fc=fc, degraded=degraded)
        for state in states:
            _finalize_group(state, fields, recs, ebs, conv_arcs, config,
                            collect_stats, out_fields, on_entry, tel=tel,
                            fc=fc, degraded=degraded)
        # Conventional compression that ran lazily inside the loop belongs
        # to conv_s, not train_s (keep the two disjoint, like the serial
        # engine).
        train_time = ((time.time() - t_train0)
                      - (stage.stats.conv_s - conv_before))

        timing = obs_lib.build_timing(
            tel, total_s=time.time() - t0, conv_s=stage.stats.conv_s,
            train_s=train_time, conv_stage=stage.stats.as_dict(),
            degraded_fields=degraded)
        with tel.span("assemble"):
            return neurlz.assemble_archive(fields, out_fields, config,
                                           timing)


def decompress(arc) -> dict[str, np.ndarray]:
    """Batched decode: all enhancer inference in one dispatch per signature,
    and the conventional stage amortized through the registry's symmetric
    ``decompress_batched`` capability (same-``decode_key`` archives decode
    as one stacked eager dispatch).

    Output is bit-identical to ``neurlz.decompress(arc, engine="serial")``
    because the per-field inference graph — and, contractually, the batched
    conventional decode — are the same.
    """
    slice_axis = arc["slice_axis"]
    recs = registry.decompress_many(
        {name: e["conv"] for name, e in arc["fields"].items()})

    # Group fields by inference signature so each dispatch is shape-static.
    # Degraded (conv-only) entries have no network: their conventional
    # reconstruction IS the decode, same as the serial path.
    sig_groups: dict[tuple, list[str]] = {}
    prepared: dict[str, tuple] = {}
    out = {}
    for name, e in arc["fields"].items():
        if e.get("degraded"):
            out[name] = np.asarray(recs[name])
            continue
        net_cfg, params = neurlz.decode_entry_net(e)
        aux = [recs[a] for a in e["aux"]]
        stats = [tuple(s) for s in e["stats"]]
        inputs, _, _ = online_trainer.make_dataset(
            recs[name], None, e["abs_eb"], aux=aux, slice_axis=slice_axis,
            stats=stats)
        sig = (inputs.shape, net_cfg.regulated, net_cfg.skip)
        sig_groups.setdefault(sig, []).append(name)
        prepared[name] = (net_cfg, params, jnp.asarray(inputs))

    for sig, names in sig_groups.items():
        spec = tuple((prepared[n][0].regulated, prepared[n][0].skip)
                     for n in names)
        resids = _predict_group(tuple(prepared[n][1] for n in names),
                                tuple(prepared[n][2] for n in names),
                                spec=spec)
        for f, name in enumerate(names):
            out[name] = neurlz.apply_decoded_entry(
                arc["fields"][name], recs[name], np.asarray(resids[f]),
                slice_axis)
    return {name: out[name] for name in arc["fields"]}
