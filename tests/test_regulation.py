import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import regulation as R


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_strict_patch_always_within_bound(seed):
    rng = np.random.default_rng(seed)
    eb = float(rng.uniform(1e-4, 1e-1))
    orig = rng.standard_normal((6, 8, 8)).astype(np.float32)
    decomp = orig + rng.uniform(-eb, eb, orig.shape).astype(np.float32)
    resid_norm = rng.uniform(-1, 1, orig.shape).astype(np.float32)
    enh = R.enhance(decomp, resid_norm, eb)
    mask = R.outlier_mask(orig, enh, eb)
    final = R.apply_strict(enh, decomp, mask)
    chk = R.check_bound(orig, final, eb, "strict")
    assert chk["ok"], chk


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_regulated_enhance_within_2x(seed):
    rng = np.random.default_rng(seed)
    eb = float(rng.uniform(1e-4, 1e-1))
    orig = rng.standard_normal((6, 8, 8)).astype(np.float64)
    decomp = orig + rng.uniform(-eb, eb, orig.shape)
    resid_norm = np.tanh(rng.standard_normal(orig.shape))  # in (-1, 1)
    enh = R.enhance(decomp, resid_norm, eb, out_dtype=np.float64)
    chk = R.check_bound(orig, enh, eb, "relaxed")
    assert chk["ok"], chk
