"""Roofline-term derivation from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9         (HBM bandwidth)
    collective = wire_bytes_per_device / 50e9         (per-link ICI)

cost_analysis() supplies FLOPs/bytes for the per-device SPMD module.
Collective bytes are NOT in cost_analysis — we parse the post-optimization
HLO and sum per-op wire traffic with ring-algorithm factors:

    all-gather        result × (n−1)/n
    reduce-scatter    result × (n−1)          (operand = result × n)
    all-reduce        result × 2(n−1)/n
    all-to-all        result × (n−1)/n
    collective-permute result × 1

``MODEL_FLOPS`` (6·N_active·D for training, 2·N_active·D for inference) over
HLO FLOPs is the "useful-compute" ratio — it exposes remat/dispatch waste.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^)\n]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_TUPLE_COLL_RE = re.compile(
    r"=\s+\(((?:[a-z0-9]+\[[0-9,]*\][^,)]*,?\s*)+)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def wire_factor(kind: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-gather":
        return (n - 1) / n
    if kind == "reduce-scatter":
        return float(n - 1)
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic summary from post-SPMD HLO text."""
    per_kind: dict[str, float] = {}
    raw_result_bytes: dict[str, int] = {}
    count: dict[str, int] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        shapes: list[tuple[str, str]] = []
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_COLL_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", mt.group(1))
        if kind is None:
            continue
        if "-done(" in line:   # async pair: count the start only
            continue
        n = _group_size(line)
        rb = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        per_kind[kind] = per_kind.get(kind, 0.0) + rb * wire_factor(kind, n)
        raw_result_bytes[kind] = raw_result_bytes.get(kind, 0) + rb
        count[kind] = count.get(kind, 0) + 1
    total = sum(per_kind.values())
    return {"wire_bytes": total, "per_kind_wire": per_kind,
            "per_kind_result_bytes": raw_result_bytes, "per_kind_count": count}


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float) -> dict:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    coll = wire_bytes_per_device / ICI_BW
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", coll), key=lambda kv: kv[1])[0]
    bound = max(compute, memory, coll)
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        # fraction of roofline-limited time spent on useful compute
        "compute_fraction_of_bound": compute / bound if bound else 0.0,
    }


def model_flops(cfg, shape, n_chips: int) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference), per chip."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence per step
        total = 2.0 * n_active * shape.global_batch
    return total / n_chips
