"""GQA/MQA attention with qk-norm, RoPE, sliding windows, and a KV cache.

Train/prefill path computes full (optionally windowed) causal attention;
decode path attends one new token against a fixed-capacity cache.  Head
projections are tensor-parallel (``w_in``/``w_out`` naming — see
``repro.distributed.sharding``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from .layers import apply_rope, dense_init, head_rmsnorm

NEG = -1e30


def init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "w_q_in": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "w_k_in": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "w_v_in": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "w_o_out": dense_init(ks[3], cfg.n_heads * hd, d, dtype,
                              scale=1.0 / np.sqrt(cfg.n_heads * hd)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p, cfg, x, positions, theta):
    b, s, _ = x.shape
    hd = cfg.hd
    q = (x @ p["w_q_in"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["w_k_in"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["w_v_in"]).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = head_rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    # Anchor SPMD: batch over (pod,data), heads over model (falls back to
    # head_dim for small-KV archs via the divisibility guard).
    q = constrain(q, ("batch", None, "model", None))
    k = constrain(k, ("batch", None, "model", None))
    v = constrain(v, ("batch", None, "model", None))
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """q: [B,S,H,D]; k,v: [B,T,KV,D]; mask: [B or 1, 1, S, T] additive.

    Dense path — used for decode (S=1) and small smoke shapes; training and
    prefill go through :func:`_sdpa_chunked` (the S² score tensor would
    dominate HBM otherwise)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    groups = h // kv
    q = q.reshape(b, s, kv, groups, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    scores = scores + mask[:, :, None, :, :]     # broadcast over groups
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(b, s, h, hd)


def _sdpa_chunked(q, k, v, cfg, *, causal: bool, window: int | None,
                  cq: int = 512, ck: int = 1024, skip_uncausal: bool = False):
    """Flash-style online-softmax attention: O(S·chunk) memory, never
    materializing the [S, T] score matrix (TPU adaptation of FA for XLA).

    Both paths remat the per-q-chunk work (``jax.checkpoint``): the backward
    pass recomputes block scores/probs exactly like FlashAttention's bwd,
    so nothing S²-sized is ever saved.

    ``skip_uncausal=True`` enumerates only the lower-triangular (and
    in-window) chunk pairs — the §Perf compute-term optimization; the
    baseline scans all chunk pairs with masking (same FLOPs as dense).
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    cq = min(cq, s)
    ck = min(ck, s)
    assert s % cq == 0 and s % ck == 0, (s, cq, ck)
    nq, nk = s // cq, s // ck
    qc = q.reshape(b, nq, cq, kv, g, hd).astype(jnp.float32) / np.sqrt(hd)
    kc = k.reshape(b, nk, ck, kv, hd).astype(jnp.float32)
    vc = v.reshape(b, nk, ck, kv, hd)
    qc = constrain(qc, ("batch", None, None, "model", None, None))
    kc = constrain(kc, ("batch", None, None, "model", None))
    vc = constrain(vc, ("batch", None, None, "model", None))

    def bias_for(i, j):
        """Additive f32 mask bias [cq, ck] (no boolean `where` on the big
        score tensor — keeps SPMD from materializing broadcast predicates)."""
        qpos = i * cq + jnp.arange(cq, dtype=jnp.int32)
        kpos = j * ck + jnp.arange(ck, dtype=jnp.int32)
        bias = jnp.zeros((cq, ck), jnp.float32)
        if causal:
            bias = bias + jnp.where(kpos[None, :] <= qpos[:, None], 0.0, -1e30)
        if window is not None:
            bias = bias + jnp.where((qpos[:, None] - kpos[None, :]) < window,
                                    0.0, -1e30)
        return bias

    def online_update(carry, sij, vblk):
        m, l, acc = carry
        m_new = jnp.maximum(m, sij.max(-1))
        p = jnp.exp(sij - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        a_new = acc * alpha[..., None] + jnp.einsum(
            "bqkgt,btkd->bqkgd", p, vblk.astype(jnp.float32))
        return m_new, l_new, a_new

    def row_for(qblk, i, js):
        """One q-chunk against the kv chunks listed in ``js``."""
        m = jnp.full((b, cq, kv, g), -1e30, jnp.float32)
        l = jnp.zeros((b, cq, kv, g), jnp.float32)
        acc = jnp.zeros((b, cq, kv, g, hd), jnp.float32)

        def kv_step(carry, j):
            kblk = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
            sij = jnp.einsum("bqkgd,btkd->bqkgt", qblk, kblk)
            sij = sij + bias_for(i, j)[None, :, None, None, :]
            return online_update(carry, sij, vblk), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m, l, acc), js)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    if skip_uncausal and causal:
        # Exact lower-triangle enumeration (§Perf compute-term optimization):
        # only live chunk pairs are computed; rows with equal kv-counts could
        # be batched, but an unrolled python loop over nq keeps HLO simple
        # (nq is small — 8 at 4k/512).
        out_rows = []
        for i in range(nq):
            js = [j for j in range(nk)
                  if (j * ck <= i * cq + cq - 1)
                  and (window is None or (i * cq - (j * ck + ck - 1)) < window)]
            row = jax.checkpoint(
                lambda qblk, jarr, i=i: row_for(qblk, i, jarr))(
                    qc[:, i], jnp.asarray(js, jnp.int32))
            out_rows.append(row)
        out = jnp.stack(out_rows, axis=1)
        return out.reshape(b, s, h, hd).astype(v.dtype)

    # Baseline: scan over q chunks, inner scan over all kv chunks (masked).
    all_js = jnp.arange(nk, dtype=jnp.int32)

    @jax.checkpoint
    def q_chunk_fn(qblk, i):
        return row_for(qblk, i, all_js)

    def q_chunk(_, inp):
        qblk, i = inp                                          # [b,cq,kv,g,d]
        return None, q_chunk_fn(qblk, i)

    _, out = jax.lax.scan(q_chunk, None,
                          (jnp.moveaxis(qc, 1, 0),
                           jnp.arange(nq, dtype=jnp.int32)))
    out = jnp.moveaxis(out, 0, 1)                              # [b,nq,cq,kv,g,d]
    return out.reshape(b, s, h, hd).astype(v.dtype)


def causal_mask(s: int, window: int | None, dtype=jnp.float32):
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    allow = j <= i
    if window is not None:
        allow &= (i - j) < window
    return jnp.where(allow, 0.0, NEG).astype(dtype)[None, None]   # [1,1,S,S]


def full_mask(s: int, dtype=jnp.float32):
    return jnp.zeros((1, 1, s, s), dtype)


DENSE_SDPA_MAX = 1024  # dense fallback for small (smoke-test) shapes


def forward(p, cfg, x, positions, *, window=None, theta=None, mask=None,
            skip_uncausal: bool = False):
    """Train/prefill attention.  Returns (out, (k, v)) for cache capture."""
    theta = cfg.rope_theta if theta is None else theta
    q, k, v = _project_qkv(p, cfg, x, positions, theta)
    s = x.shape[1]
    if s <= DENSE_SDPA_MAX:
        if mask is None:
            mask = causal_mask(s, window) if cfg.causal else full_mask(s)
        out = _sdpa(q, k, v, mask, cfg)
    else:
        out = _sdpa_chunked(q, k, v, cfg, causal=cfg.causal, window=window,
                            skip_uncausal=skip_uncausal)
    out = constrain(out, ("batch", None, "model", None))
    b = x.shape[0]
    out = out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["w_o_out"]
    return out, (k, v)


def init_cache(cfg, batch: int, max_len: int, dtype):
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
    }


def decode_step(p, cfg, x, cache, pos, *, window=None, theta=None,
                ring: bool = False):
    """One-token decode.  x: [B,1,D]; pos: [] int32 (same for all rows).

    Returns (out [B,1,D], new_cache).  ``ring=True`` treats the cache as a
    circular buffer of the last ``cache_len`` tokens (sliding-window layers
    cache only the window): writes wrap, and a slot is attendable iff it has
    been written (``j <= pos`` before the first wrap, everything after).
    RoPE always uses the true absolute position.
    """
    theta = cfg.rope_theta if theta is None else theta
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions, theta)
    t = cache["k"].shape[1]
    write = jnp.remainder(pos, t) if ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, write, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, write, axis=1)
    j = jnp.arange(t)
    if ring:
        allow = (j <= pos) | (pos >= t)
    else:
        allow = j <= pos
        if window is not None:
            allow &= (pos - j) < window
    mask = jnp.where(allow, 0.0, NEG)[None, None, None, :]        # [1,1,1,T]
    out = _sdpa(q, k, v, mask, cfg)
    out = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ p["w_o_out"]
    return out, {"k": k, "v": v}
