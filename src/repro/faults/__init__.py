"""Fault tolerance for the compression stack: injection, retry, degradation.

Three cooperating pieces, wired through ``NeurLZConfig.faults`` /
``NeurLZ(faults=...)`` the same way telemetry rides on
``config.telemetry``:

* :class:`FaultInjector` — deterministic site/invocation fault registry
  (``"writer.add_entry"``, ``"train.<field>"``, ``"decode.entry"``,
  ``"reader.load"``).  Tests and chaos runs schedule exact failures;
  production leaves it ``None`` and every check is a shared no-op.
* :class:`RetryPolicy` / :func:`retry_with_backoff` — bounded exponential
  backoff around transient I/O sites (archive writer, streaming reader
  thread, ``Archive.decode``), counted on telemetry as ``faults.retries``.
* **Graceful degradation** — a per-field enhancer failure (non-finite
  loss, injected fault, OOM) downgrades that field to a conv-only entry
  that still honors its exact error bound (the conventional stage alone
  guarantees ``|x - x'| <= eb``), recorded in the entry
  (``entry["degraded"]``), counted as ``faults.degraded``, and listed in
  ``timing["degraded_fields"]`` — instead of aborting the snapshot.
  Degradation *reasons* are normalized (:func:`degrade_reason`) so all
  three engines emit byte-identical degraded entries for the same
  failure.

The straggler watchdog reuses the seeded
:class:`repro.checkpoint.fault_tolerance.StepWatchdog`: give
``FaultConfig.straggler_deadline_s`` a value and the streaming scheduler
flags field groups that exceed it via ``faults.stragglers`` telemetry.

Like ``repro.obs`` this package imports neither jax nor the engines, so
building a :class:`FaultConfig` never flips the x64 switch.
"""
from __future__ import annotations

import dataclasses

from ..checkpoint.fault_tolerance import StepWatchdog  # noqa: F401
from .injector import FaultInjector, InjectedFault, NULL_INJECTOR
from .retry import RetryPolicy, retry_with_backoff

__all__ = [
    "FaultConfig", "FaultInjector", "InjectedFault", "RetryPolicy",
    "StepWatchdog", "retry_with_backoff", "of", "DEFAULT",
    "is_degradable", "degrade_reason", "NULL_INJECTOR",
]

# Failures eligible for conv-only degradation.  Deliberately narrow: a
# genuine bug (shape mismatch, TypeError) must still crash loudly — only
# the failure modes a long-running HPC job meets (injected chaos, host or
# device memory exhaustion, float traps) downgrade a field.
DEGRADABLE_EXCEPTIONS = (InjectedFault, MemoryError, FloatingPointError)


def is_degradable(exc: BaseException) -> bool:
    """True when a per-field enhancer failure should degrade the field to
    conv-only instead of aborting the snapshot."""
    if isinstance(exc, DEGRADABLE_EXCEPTIONS):
        return True
    # jax device OOM surfaces as XlaRuntimeError("RESOURCE_EXHAUSTED: ...")
    # — matched by message so this package never imports jax.
    return "RESOURCE_EXHAUSTED" in str(exc)


def degrade_reason(exc: BaseException | None = None) -> str:
    """Normalized degradation reason recorded in the entry.  The same
    failure must yield the same string in every engine — the cross-engine
    byte-identity contract extends to degraded entries."""
    if exc is None:
        return "non-finite-loss"
    if isinstance(exc, InjectedFault):
        return "injected"
    return f"error:{type(exc).__name__}"


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault-tolerance knobs carried by ``NeurLZConfig.faults``.

    ``injector=None`` disables injection (production), ``retry=None``
    disables retries (fail fast — the pre-PR-8 behavior), ``degrade``
    controls conv-only degradation, ``straggler_deadline_s`` arms the
    per-group watchdog on the streaming scheduler.
    """

    injector: FaultInjector | None = None
    retry: RetryPolicy | None = None
    degrade: bool = True
    straggler_deadline_s: float | None = None

    def check(self, site: str) -> None:
        """Injection probe for ``site`` (no-op without an injector)."""
        if self.injector is not None:
            self.injector.check(site)

    def run(self, fn, *, site: str, tel=None):
        """Probe ``site`` then run ``fn`` — under the retry policy when one
        is set, else one straight attempt.  The probe sits *inside* the
        retried closure, so a transiently-planned injection heals on
        retry exactly like a real transient I/O error."""
        from ..obs import telemetry as obs_lib

        def attempt():
            self.check(site)
            return fn()

        if self.retry is None:
            return attempt()
        return retry_with_backoff(attempt, self.retry, site=site,
                                  tel=tel if tel is not None else obs_lib.NULL)


#: Shared default: no injection, no retries, degradation on.
DEFAULT = FaultConfig()


def of(config) -> FaultConfig:
    """The :class:`FaultConfig` carried by a config-like object
    (``.faults`` attribute), or :data:`DEFAULT`."""
    fc = getattr(config, "faults", None)
    return fc if fc is not None else DEFAULT
